"""Pluggable surrogates over the design space — pure numpy, no new deps.

Both surrogates map an observed design matrix to a predictive mean *and*
uncertainty (the acquisition functions need both):

- :class:`ForestSurrogate` — a bootstrap ensemble of depth-limited
  regression trees with random feature subsets (random-forest-style).
  The ensemble spread is the uncertainty.  Robust on the one-hot,
  interaction-heavy sweep axes (page policy flips the objective by 5x on
  some accelerators and barely moves it on others), needs no kernel
  tuning, and fits hundreds of observations in milliseconds.
- :class:`GPSurrogate` — GP-lite: an RBF-kernel Gaussian process with a
  median-distance lengthscale heuristic and a jitter nugget.  Smoother
  extrapolation on small observation sets; O(n^3) in observations, which
  is irrelevant at search budgets.

Everything is deterministic under the caller's ``numpy.random.Generator``
— tree bootstraps, feature subsets — so a seeded search replays exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class _Tree:
    """Array-coded regression tree: ``feature[i] < 0`` marks a leaf."""

    feature: np.ndarray
    threshold: np.ndarray
    left: np.ndarray
    right: np.ndarray
    value: np.ndarray
    depth: int

    def predict(self, X: np.ndarray) -> np.ndarray:
        node = np.zeros(len(X), dtype=np.int64)
        for _ in range(self.depth + 1):
            f = self.feature[node]
            internal = f >= 0
            if not internal.any():
                break
            fx = X[np.arange(len(X)), np.maximum(f, 0)]
            go_left = fx <= self.threshold[node]
            node = np.where(internal,
                            np.where(go_left, self.left[node],
                                     self.right[node]),
                            node)
        return self.value[node]


def _grow_tree(X: np.ndarray, y: np.ndarray, rng: np.random.Generator,
               max_depth: int, min_leaf: int,
               feature_frac: float) -> _Tree:
    feature, threshold, left, right, value = [], [], [], [], []
    d = X.shape[1]
    n_try = max(1, int(round(d * feature_frac)))

    def leaf(idx) -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        value.append(float(y[idx].mean()))
        return len(feature) - 1

    def build(idx: np.ndarray, depth: int) -> int:
        ys = y[idx]
        if (depth >= max_depth or len(idx) < 2 * min_leaf
                or ys.max() - ys.min() <= 0):
            return leaf(idx)
        best = None  # (sse, feature, threshold, mask)
        for f in rng.choice(d, size=n_try, replace=False):
            xs = X[idx, f]
            cuts = np.unique(xs)
            if len(cuts) < 2:
                continue
            for t in (cuts[:-1] + cuts[1:]) / 2.0:
                m = xs <= t
                nl = int(m.sum())
                if nl < min_leaf or len(idx) - nl < min_leaf:
                    continue
                yl, yr = ys[m], ys[~m]
                sse = (((yl - yl.mean()) ** 2).sum()
                       + ((yr - yr.mean()) ** 2).sum())
                if best is None or sse < best[0]:
                    best = (sse, int(f), float(t), m)
        if best is None:
            return leaf(idx)
        _, f, t, m = best
        node = leaf(idx)  # placeholder; overwrite as internal
        feature[node] = f
        threshold[node] = t
        left[node] = build(idx[m], depth + 1)
        right[node] = build(idx[~m], depth + 1)
        return node

    build(np.arange(len(y)), 0)
    return _Tree(np.array(feature), np.array(threshold),
                 np.array(left), np.array(right), np.array(value),
                 max_depth)


class ForestSurrogate:
    """Bootstrap ensemble of regression trees; spread = uncertainty."""

    def __init__(self, n_trees: int = 24, max_depth: int = 8,
                 min_leaf: int = 2, feature_frac: float = 0.8):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.feature_frac = feature_frac
        self._trees: list[_Tree] = []
        self._y_std = 1.0

    def fit(self, X: np.ndarray, y: np.ndarray,
            rng: np.random.Generator) -> "ForestSurrogate":
        n = len(y)
        self._y_std = float(y.std()) or 1.0
        self._trees = []
        for _ in range(self.n_trees):
            boot = rng.integers(0, n, size=n)
            self._trees.append(_grow_tree(X[boot], y[boot], rng,
                                          self.max_depth, self.min_leaf,
                                          self.feature_frac))
        return self

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        preds = np.stack([t.predict(X) for t in self._trees])
        # floor the spread: a pool point all trees agree on is still not
        # a certainty — the ensemble only saw bootstraps of the probes
        return preds.mean(axis=0), np.maximum(preds.std(axis=0),
                                              1e-3 * self._y_std)


class GPSurrogate:
    """RBF-kernel GP with median-distance lengthscale and jitter nugget."""

    def __init__(self, lengthscale: float | None = None,
                 noise: float = 1e-3):
        self.lengthscale = lengthscale
        self.noise = noise
        self._X: np.ndarray | None = None

    @staticmethod
    def _sqdist(A: np.ndarray, B: np.ndarray) -> np.ndarray:
        return np.maximum(
            (A * A).sum(1)[:, None] + (B * B).sum(1)[None, :]
            - 2.0 * (A @ B.T), 0.0)

    def fit(self, X: np.ndarray, y: np.ndarray,
            rng: np.random.Generator) -> "GPSurrogate":
        self._X = X
        self._y_mean = float(y.mean())
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        d2 = self._sqdist(X, X)
        if self.lengthscale is None:
            off = d2[np.triu_indices(len(X), k=1)]
            med = float(np.median(off[off > 0])) if (off > 0).any() else 1.0
            self._ls2 = med
        else:
            self._ls2 = self.lengthscale ** 2
        K = np.exp(-0.5 * d2 / self._ls2)
        K[np.diag_indices_from(K)] += self.noise + 1e-8
        self._chol = np.linalg.cholesky(K)
        self._alpha = np.linalg.solve(
            self._chol.T, np.linalg.solve(self._chol, yn))
        return self

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        Ks = np.exp(-0.5 * self._sqdist(X, self._X) / self._ls2)
        mean = Ks @ self._alpha
        v = np.linalg.solve(self._chol, Ks.T)
        var = np.maximum(1.0 - (v * v).sum(axis=0), 1e-9)
        return (mean * self._y_std + self._y_mean,
                np.sqrt(var) * self._y_std)


SURROGATES = {
    "forest": ForestSurrogate,
    "gp": GPSurrogate,
}


def make_surrogate(name: str):
    try:
        return SURROGATES[name]()
    except KeyError:
        raise ValueError(f"unknown surrogate {name!r} "
                         f"(available: {', '.join(SURROGATES)})")
