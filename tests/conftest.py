import os
import sys

# Tests run on the single real CPU device (the 512-device placeholder env is
# set ONLY inside launch/dryrun.py, never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.graph.generators import rmat, grid_road, preferential


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (dry-run compiles, full drivers)"
    )


@pytest.fixture(scope="session")
def small_rmat():
    return rmat(10, edge_factor=8, seed=3, name="small_rmat")


@pytest.fixture(scope="session")
def mid_rmat():
    return rmat(13, edge_factor=8, seed=5, name="mid_rmat")


@pytest.fixture(scope="session")
def road_graph():
    return grid_road(48, seed=7, name="road48")


@pytest.fixture(scope="session")
def skewed_graph():
    return preferential(4096, 6, seed=9, name="pa4096")


def bfs_oracle(n, src, dst, root):
    """Plain-python BFS levels oracle."""
    from collections import deque, defaultdict

    adj = defaultdict(list)
    for s, d in zip(src.tolist(), dst.tolist()):
        adj[s].append(d)
    level = np.full(n, np.inf, dtype=np.float32)
    level[root] = 0
    q = deque([root])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if level[v] == np.inf:
                level[v] = level[u] + 1
                q.append(v)
    return level


def wcc_oracle(n, src, dst):
    """Union-find weakly-connected components, labelled by min vertex id."""
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for s, d in zip(src.tolist(), dst.tolist()):
        rs, rd = find(s), find(d)
        if rs != rd:
            parent[max(rs, rd)] = min(rs, rd)
    # label = min id in component
    labels = np.zeros(n, dtype=np.float32)
    roots = {}
    for v in range(n):
        r = find(v)
        if r not in roots:
            roots[r] = r  # since we always parent to min, root IS the min id
        labels[v] = roots[r]
    return labels
