"""Declarative scenario sweeps over the simulation environment's axes.

A :class:`SweepSpec` names the performance dimensions the paper sweeps —
accelerator x problem x graph x memory technology x configuration — and
``expand()`` resolves the cross-product into fully-typed :class:`Scenario`
records.  Invalid combinations (a weighted problem on an accelerator without
weight support, multi-channel DRAM on a single-channel design, an interval
size the model rejects) are filtered into :class:`Skipped` records instead of
crashing mid-sweep.

Scenarios are frozen, hashable and picklable: they are the unit of work of
``repro.sweep.runner`` and the input of the content-addressed result cache
(``repro.sweep.cache``).
"""
from __future__ import annotations

import dataclasses

from repro.configs.graphsim import default_config
from repro.core import semexec
from repro.core.accelerators import ACCELERATORS
from repro.core.accelerators.base import AccelConfig
from repro.core.dram import (
    DRAM_CONFIGS,
    DRAMConfig,
    MAPPING_SCHEMES,
    PAGE_POLICIES,
    AddressMapping,
    dram_config,
)
from repro.graph.generators import PAPER_GRAPHS, GraphSpec
from repro.graph.layout import REORDERS, validate_interval_scale
from repro.graph.problems import PROBLEMS


@dataclasses.dataclass(frozen=True)
class ConfigOverride:
    """One point of a configuration axis (e.g. an ablation): the fields set
    here replace the accelerator's default :class:`AccelConfig` fields."""

    label: str = ""
    interval_size: int | None = None
    n_pes: int | None = None
    optimizations: frozenset | None = None
    engine: str | None = None

    def apply(self, cfg: AccelConfig) -> AccelConfig:
        kw = {
            f: getattr(self, f)
            for f in ("interval_size", "n_pes", "optimizations", "engine")
            if getattr(self, f) is not None
        }
        return dataclasses.replace(cfg, **kw) if kw else cfg


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-resolved simulation point: everything ``run_accelerator``
    needs, with no late binding — hashable, picklable, cacheable."""

    graph: GraphSpec
    accelerator: str
    problem: str
    dram: DRAMConfig
    config: AccelConfig
    root: int = 0
    label: str = ""  # ConfigOverride label (e.g. ablation name)

    @property
    def scenario_id(self) -> str:
        """Human-readable identity for progress lines and error reports.
        Memory-controller and layout axes appear only when non-default, so
        historical ids are unchanged."""
        dram = f"{self.dram.name}x{self.dram.channels}"
        if self.dram.pseudo_channels:
            dram += "-pc"
        parts = [self.graph.name, self.accelerator, self.problem, dram]
        m = self.dram.mapping
        if m.scheme != "row" or m.channel_lines != 1:
            parts.append(m.label)
        if self.dram.page_policy != "open":
            parts.append(self.dram.page_policy)
        if self.config.reorder != "identity":
            parts.append(self.config.reorder)
        if self.config.interval_scale != 1:
            parts.append(f"ivx{self.config.interval_scale}")
        if self.config.semexec != "numpy":
            parts.append(self.config.semexec)
        if self.label:
            parts.append(self.label)
        return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class Skipped:
    """An invalid axis combination, recorded instead of executed."""

    graph: str
    accelerator: str
    problem: str
    dram: str
    label: str
    reason: str


def _as_graph_spec(g: str | GraphSpec) -> GraphSpec:
    return PAPER_GRAPHS[g] if isinstance(g, str) else g


def _as_dram_axis(d) -> tuple[str, int | None]:
    return d if isinstance(d, tuple) else (d, None)


def _as_mapping(m: str | AddressMapping) -> AddressMapping:
    """Parse a mapping-axis token: an :class:`AddressMapping`, a scheme
    name (``row`` | ``bank`` | ``bank_xor``), or ``scheme@lines`` with an
    explicit channel-interleave granularity (e.g. ``row@32``)."""
    if isinstance(m, AddressMapping):
        return m
    scheme, _, g = str(m).partition("@")
    try:
        lines = int(g) if g else 1
    except ValueError:
        raise ValueError(f"bad channel-interleave granularity in {m!r}")
    return AddressMapping(scheme, lines)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Cross-product sweep definition.

    Axes:
      accelerators: model names from ``ACCELERATORS``.
      graphs: ``PAPER_GRAPHS`` keys or inline :class:`GraphSpec` recipes.
      problems: ``PROBLEMS`` keys.
      drams: DRAM preset names, or ``(name, channels)`` pairs; an explicit
        channel count also sets ``n_pes`` on accelerators that pair PEs with
        memory channels (HitGraph, ThunderGP — the paper's Tab. 7 setup).
      mappings: memory-controller address mappings — scheme names
        (``row`` | ``bank`` | ``bank_xor``), ``scheme@lines`` tokens with an
        explicit channel-interleave granularity, or
        :class:`repro.core.dram.AddressMapping` instances.
      page_policies: row-buffer page policies (``open`` | ``closed``).
      pseudo_channels: HBM pseudo-channel mode on/off; ``True`` is filtered
        to :class:`Skipped` on non-HBM presets.
      overrides: :class:`ConfigOverride` axis (ablations, interval sizes...).
      reorders: graph-layout vertex reorderings applied before partitioning
        (``identity`` | ``degree`` | ``random`` | ``bfs`` —
        ``repro.graph.layout.REORDERS``); semantics are layout-invariant,
        only partition shapes and traces move.
      interval_scales: power-of-two multipliers on each accelerator's
        ``interval_size`` (partition granularity axis); combinations a
        model rejects (ForeGraph past the 65,536 cap) are filtered to
        :class:`Skipped`.
      engines: semantic execution engines (``numpy`` | ``device`` —
        ``repro.core.semexec.ENGINES``); a requested ``device`` engine
        falls back to numpy (with a warning) on accelerator/problem pairs
        without a device path, and the result rows record the engine that
        actually ran.

    Expansion order is graphs, accelerators, problems, drams, mappings,
    page policies, pseudo-channels, overrides, reorders, interval scales,
    engines — stable, so result rows are deterministic regardless of
    execution order.
    """

    name: str
    accelerators: tuple[str, ...]
    graphs: tuple[str | GraphSpec, ...]
    problems: tuple[str, ...] = ("bfs",)
    drams: tuple[str | tuple[str, int | None], ...] = ("default",)
    mappings: tuple[str | AddressMapping, ...] = ("row",)
    page_policies: tuple[str, ...] = ("open",)
    pseudo_channels: tuple[bool, ...] = (False,)
    overrides: tuple[ConfigOverride, ...] = (ConfigOverride(),)
    reorders: tuple[str, ...] = ("identity",)
    interval_scales: tuple[int, ...] = (1,)
    engines: tuple[str, ...] = ("numpy",)

    def _validate(self) -> None:
        """Clean errors for unknown axis names (instead of a KeyError deep
        in the expansion)."""
        def check(kind, names, known):
            unknown = sorted(set(names) - set(known))
            if unknown:
                raise ValueError(
                    f"unknown {kind} {', '.join(map(repr, unknown))}; "
                    f"available: {', '.join(known)}"
                )

        check("accelerator(s)", self.accelerators, ACCELERATORS)
        check("problem(s)", self.problems, PROBLEMS)
        check("graph(s)", [g for g in self.graphs if isinstance(g, str)], PAPER_GRAPHS)
        check("DRAM preset(s)", [_as_dram_axis(d)[0] for d in self.drams], DRAM_CONFIGS)
        bad = [c for _, c in map(_as_dram_axis, self.drams)
               if c is not None and c < 1]
        if bad:
            raise ValueError(f"channel counts must be >= 1, got {bad}")
        check("address-mapping scheme(s)",
              [m.scheme if isinstance(m, AddressMapping)
               else str(m).partition("@")[0] for m in self.mappings],
              MAPPING_SCHEMES)
        check("page polic(ies)", self.page_policies, PAGE_POLICIES)
        bad_pc = [p for p in self.pseudo_channels if not isinstance(p, bool)]
        if bad_pc:
            raise ValueError(f"pseudo_channels must be booleans, got {bad_pc}")
        check("reorder(s)", self.reorders, REORDERS)
        for scale in self.interval_scales:
            validate_interval_scale(scale)
        check("engine(s)", self.engines, semexec.ENGINES)

    def _memory_axes(self):
        """The resolved (mapping, page_policy, pseudo_channels) cross
        product, in spec order."""
        return [
            (_as_mapping(m), pp, pc)
            for m in self.mappings
            for pp in self.page_policies
            for pc in self.pseudo_channels
        ]

    def expand(self) -> tuple[list[Scenario], list[Skipped]]:
        self._validate()
        scenarios: list[Scenario] = []
        skipped: list[Skipped] = []
        mem_axes = self._memory_axes()
        for graph in self.graphs:
            gspec = _as_graph_spec(graph)
            for accel in self.accelerators:
                cls = ACCELERATORS[accel]
                for prob in self.problems:
                    problem = PROBLEMS[prob]
                    for dram_axis in self.drams:
                        dname, channels = _as_dram_axis(dram_axis)
                        base_dram = DRAM_CONFIGS[dname]

                        seen_reasons: set[tuple[str, str]] = set()

                        def skip(reason: str, label: str = ""):
                            # dedup per (dram axis): the same incompatibility
                            # recurring across memory-axis combinations is one
                            # record, not mappings x policies x pc copies
                            if (reason, label) in seen_reasons:
                                return
                            seen_reasons.add((reason, label))
                            skipped.append(Skipped(
                                graph=gspec.name, accelerator=accel,
                                problem=prob, dram=dname,
                                label=label, reason=reason,
                            ))

                        # axis-independent incompatibilities: one record per
                        # (graph, accel, problem, dram), not one per memory
                        # axis x override combination
                        if problem.needs_weights and not cls.supports_weights:
                            skip(f"{accel} does not support weighted problems")
                            continue
                        if channels and channels > 1 and not cls.supports_multichannel:
                            skip(f"{accel} does not support multi-channel memory")
                            continue
                        for mapping, policy, pc in mem_axes:
                            reason = None
                            if pc and base_dram.standard != "HBM":
                                reason = (f"pseudo-channels require HBM "
                                          f"({dname} is {base_dram.standard})")
                            elif mapping.channel_lines != 1 and not pc:
                                reason = (f"channel-interleave granularity "
                                          f"({mapping.label}) only acts on the "
                                          f"pseudo-channel deal")
                            elif (mapping.scheme == "bank_xor"
                                    and base_dram.nbanks & (base_dram.nbanks - 1)):
                                reason = (f"bank_xor needs a power-of-two bank "
                                          f"count ({dname} has {base_dram.nbanks})")
                            if reason is not None:
                                skip(reason)
                                continue
                            for ov in self.overrides:
                                base_cfg = default_config(accel)
                                if channels and cls.supports_multichannel:
                                    base_cfg = dataclasses.replace(
                                        base_cfg, n_pes=channels)
                                base_cfg = ov.apply(base_cfg)
                                for reorder in self.reorders:
                                    for scale in self.interval_scales:
                                        for eng in self.engines:
                                            try:
                                                cfg = dataclasses.replace(
                                                    base_cfg, reorder=reorder,
                                                    interval_scale=scale,
                                                    semexec=eng)
                                                cls(cfg)  # model-side validation
                                            except ValueError as e:
                                                skip(str(e), ov.label)
                                                continue
                                            scenarios.append(Scenario(
                                                graph=gspec,
                                                accelerator=accel,
                                                problem=prob,
                                                dram=dram_config(
                                                    dname, channels=channels,
                                                    mapping=mapping,
                                                    page_policy=policy,
                                                    pseudo_channels=pc,
                                                ),
                                                config=cfg,
                                                root=gspec.root,
                                                label=ov.label,
                                            ))
        return scenarios, skipped

    def scenarios(self) -> list[Scenario]:
        return self.expand()[0]
