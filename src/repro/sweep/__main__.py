"""CLI for ad-hoc scenario sweeps.

    PYTHONPATH=src python -m repro.sweep \
        --accels accugraph,foregraph,hitgraph,thundergp \
        --graphs sd,db --problems bfs,pr --drams default,hbm \
        --workers 4 --cache results/sweep_cache --out results/sweep

``--channels`` crosses each DRAM preset with explicit channel counts (the
Tab. 7 axis); ``--mappings`` / ``--page-policies`` / ``--pseudo-channels``
cross in the memory-controller axes (e.g. ``--mappings row,bank_xor
--page-policies open,closed --pseudo-channels 0,1`` — invalid combinations
such as pseudo-channels on DDR4 are filtered, not errors); ``--reorders``
/ ``--interval-scales`` cross in the graph-layout axes (vertex reordering
before partitioning and power-of-two partition-granularity scaling —
combinations a model rejects, e.g. ForeGraph past its 65,536-vertex
interval cap, are likewise filtered); ``--list`` prints the expanded
scenarios (and what was filtered out) without simulating anything.

``python -m repro.sweep search`` takes the same axis flags but runs an
*adaptive search* over the expanded space — executing only a budgeted
fraction of it — instead of the full grid (see
:mod:`repro.sweep.search.cli`).
"""
from __future__ import annotations

import argparse
import sys

from repro.core.accelerators import ACCELERATORS
from repro.graph.generators import PAPER_GRAPHS
from repro.graph.problems import PROBLEMS
from repro.sweep.results import result_rows, write_csv, write_json
from repro.sweep.runner import ExecutionPolicy, run_sweep
from repro.sweep.spec import ConfigOverride, SweepSpec


def _csv_list(text: str) -> tuple[str, ...]:
    return tuple(x for x in text.split(",") if x)


_BOOL_TOKENS = {"0": False, "off": False, "false": False, "no": False,
                "1": True, "on": True, "true": True, "yes": True}


def _csv_bools(text: str, flag: str) -> tuple[bool, ...]:
    vals = []
    for tok in _csv_list(text):
        if tok.lower() not in _BOOL_TOKENS:
            raise ValueError(f"bad {flag} value {tok!r} (use 0/1 or on/off)")
        vals.append(_BOOL_TOKENS[tok.lower()])
    return tuple(vals) or (False,)


def build_spec(args: argparse.Namespace) -> SweepSpec:
    drams: tuple = _csv_list(args.drams)
    if args.channels:
        chans = [int(c) for c in _csv_list(args.channels)]
        drams = tuple((d, c) for d in drams for c in chans)
    overrides: tuple = (ConfigOverride(engine=args.engine) if args.engine
                        else ConfigOverride(),)
    try:
        scales = tuple(int(x) for x in _csv_list(args.interval_scales)) or (1,)
    except ValueError:
        raise ValueError(
            f"bad --interval-scales value in {args.interval_scales!r} "
            f"(use a comma list of power-of-two integers)")
    return SweepSpec(
        name=args.name,
        accelerators=_csv_list(args.accels),
        graphs=_csv_list(args.graphs),
        problems=_csv_list(args.problems),
        drams=drams,
        mappings=_csv_list(args.mappings) or ("row",),
        page_policies=_csv_list(args.page_policies) or ("open",),
        pseudo_channels=_csv_bools(args.pseudo_channels, "--pseudo-channels"),
        overrides=overrides,
        reorders=_csv_list(args.reorders) or ("identity",),
        interval_scales=scales,
        engines=_csv_list(args.engines) or ("numpy",),
    )


def add_spec_args(ap: argparse.ArgumentParser) -> None:
    """The sweep-axis flags, shared verbatim by ``python -m repro.sweep``
    and the serve client (``python -m repro.serve --submit``) so a spec
    means the same thing on both paths."""
    ap.add_argument("--name", default="sweep", help="sweep name (output file stem)")
    ap.add_argument("--accels", default=",".join(ACCELERATORS),
                    help=f"comma list from: {','.join(ACCELERATORS)}")
    ap.add_argument("--graphs", default="sd,db",
                    help=f"comma list from: {','.join(PAPER_GRAPHS)}")
    ap.add_argument("--problems", default="bfs",
                    help=f"comma list from: {','.join(PROBLEMS)}")
    ap.add_argument("--drams", default="default",
                    help="DRAM presets (default,ddr3,hbm,...)")
    ap.add_argument("--channels", default="",
                    help="optional channel counts crossed with --drams (e.g. 1,2,4)")
    ap.add_argument("--mappings", default="row",
                    help="address mappings (row,bank,bank_xor; scheme@lines "
                         "sets channel-interleave granularity, e.g. row@32)")
    ap.add_argument("--page-policies", default="open",
                    help="row-buffer page policies (open,closed)")
    ap.add_argument("--pseudo-channels", default="0",
                    help="HBM pseudo-channel axis (comma list of 0/1; "
                         "1 on non-HBM presets is filtered, not an error)")
    ap.add_argument("--reorders", default="identity",
                    help="graph-layout vertex reorderings applied before "
                         "partitioning (identity,degree,random,bfs)")
    ap.add_argument("--interval-scales", default="1",
                    help="power-of-two multipliers on each accelerator's "
                         "interval size (e.g. 1,2,4; combinations a model "
                         "rejects are filtered, not errors)")
    ap.add_argument("--engines", default="numpy",
                    help="semantic execution engines (numpy,device); device "
                         "falls back to numpy, with a warning, on "
                         "accelerator/problem pairs without a device path")
    ap.add_argument("--engine", default="", help="DRAM engine override (scan|fast)")


def add_policy_args(ap: argparse.ArgumentParser) -> None:
    """Robustness knobs (ExecutionPolicy), shared by the CLI runner and the
    sweep server."""
    ap.add_argument("--timeout-per-scenario", type=float, default=None,
                    metavar="SECONDS",
                    help="best-effort wall-clock bound per scenario; a "
                         "timed-out scenario becomes an error row (and "
                         "retries under --retries)")
    ap.add_argument("--retries", type=int, default=0,
                    help="re-execute a failed/timed-out scenario up to N "
                         "more times before recording the error")
    ap.add_argument("--retry-backoff", type=float, default=0.25,
                    metavar="SECONDS",
                    help="sleep before retry k is backoff * 2**k")


def build_policy(args: argparse.Namespace) -> ExecutionPolicy | None:
    if args.timeout_per_scenario is None and not args.retries:
        return None
    return ExecutionPolicy(timeout_s=args.timeout_per_scenario,
                           retries=args.retries,
                           backoff_s=args.retry_backoff)


def main(argv: list[str] | None = None) -> int:
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "search":
        from repro.sweep.search.cli import main as search_main
        return search_main(argv[1:])
    ap = argparse.ArgumentParser(prog="python -m repro.sweep", description=__doc__)
    add_spec_args(ap)
    add_policy_args(ap)
    ap.add_argument("--workers", type=int, default=0,
                    help="process-pool size; <=1 runs serially")
    ap.add_argument("--mode", default="scenario", choices=("scenario", "batch"),
                    help="batch: group all DRAM traces of a worker's chunk "
                         "into a few batched device dispatches")
    ap.add_argument("--cache", default="results/sweep_cache",
                    help="result cache directory ('' disables caching)")
    ap.add_argument("--out", default="results/sweep", help="output directory")
    ap.add_argument("--list", action="store_true",
                    help="print expanded scenarios and exit")
    args = ap.parse_args(argv)

    try:
        spec = build_spec(args)
        spec.expand()
        policy = build_policy(args)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.list:
        scenarios, skipped = spec.expand()
        for s in scenarios:
            print(f"run  {s.scenario_id}")
        for sk in skipped:
            print(f"skip {sk.graph}/{sk.accelerator}/{sk.problem}/{sk.dram}: {sk.reason}")
        print(f"{len(scenarios)} scenarios, {len(skipped)} skipped")
        return 0

    result = run_sweep(
        spec,
        cache_dir=args.cache or None,
        workers=args.workers,
        mode=args.mode,
        policy=policy,
        progress=lambda msg: print(msg, flush=True),
    )
    rows = result_rows(result, with_status=True)
    if rows:
        csv_path = f"{args.out}/{spec.name}.csv"
        write_csv(csv_path, rows)
        write_json(f"{args.out}/{spec.name}.json", rows)
        print(f"wrote {csv_path} ({len(rows)} rows)")
    else:
        print("no runnable scenarios (all combinations filtered); nothing written")
    print(result.summary())
    return 1 if result.n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
