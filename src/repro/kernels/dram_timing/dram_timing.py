"""Pallas TPU kernel: DRAM bank state-machine timing engine.

TPU adaptation of Ramulator's sequential bank state machines (see DESIGN.md
§Hardware adaptation): the request trace is streamed from HBM in blocks
(BlockSpec tiling) into VMEM; the per-bank state (open row, row-ready time,
last data slot, last activate) lives in VMEM scratch that persists across
the *sequential* TPU grid, so each grid step advances the same simulation.
The per-request dependency chain is resolved with an in-kernel fori_loop
over the VMEM-resident block (the block is the unit of HBM traffic; the
serial chain never touches HBM).

The grid is two-dimensional: ``(B, n_blocks)``.  The trailing (fastest)
dimension walks one trace's request blocks sequentially; the leading
dimension advances to the next trace of the batch, re-initialising the
VMEM bank state at its first block.  One ``pallas_call`` therefore times a
whole :class:`repro.core.engine.TraceBatch` — one device dispatch per
batch, not per trace.

Timing semantics are identical to ``repro.core.engine._scan_engine``
(`ref.py` re-exports it, and its vmapped batch form, as the oracles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

STATE_BANKS_PAD = 128  # lane-aligned bank-state vectors


def _kernel(bank_ref, row_ref, out_ref, state_ref, scalars_ref, *, nbanks,
            tCL, tRCD, tRP, tRC, tBL, lookahead, page_open, block, n_blocks):
    """One grid step: consume `block` requests of one batch row.

    state_ref: (4, STATE_BANKS_PAD) int32 VMEM scratch
       rows: 0=open_row, 1=row_ready, 2=last_data, 3=last_act
    scalars_ref: (1, 8) int32 VMEM scratch
       cols: 0=bus_free, 1=hits, 2=misses, 3=conflicts
    Scratch persists across the sequential grid; step == 0 of each batch
    row resets it so every trace starts from a cold, precharged device.
    """
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        state_ref[0, :] = jnp.full((STATE_BANKS_PAD,), -1, dtype=jnp.int32)
        state_ref[1, :] = jnp.zeros((STATE_BANKS_PAD,), dtype=jnp.int32)
        state_ref[2, :] = jnp.zeros((STATE_BANKS_PAD,), dtype=jnp.int32)
        state_ref[3, :] = jnp.full((STATE_BANKS_PAD,), -(tRC + 1), dtype=jnp.int32)
        scalars_ref[0, :] = jnp.zeros((8,), dtype=jnp.int32)

    banks = bank_ref[0, :]
    rows = row_ref[0, :]

    def body(i, carry):
        open_row, row_ready, last_data, last_act, bus_free, hits, misses, confs = carry
        b = banks[i]
        r = rows[i]
        valid = b >= 0
        bi = jnp.maximum(b, 0)
        cur = open_row[bi]
        if page_open:
            is_hit = (cur == r) & valid
            is_miss = (cur == jnp.int32(-1)) & valid
            is_conf = valid & ~is_hit & ~is_miss
        else:
            # closed-page policy: every access auto-precharges, so each
            # valid request activates (a miss) and conflicts cannot occur
            is_hit = jnp.bool_(False) & valid
            is_miss = valid
            is_conf = jnp.bool_(False) & valid

        horizon = jnp.maximum(bus_free - lookahead, 0)
        t_pre = jnp.maximum(last_data[bi], horizon)
        t_act_conf = jnp.maximum(t_pre + tRP, last_act[bi] + tRC)
        t_act_miss = jnp.maximum(jnp.maximum(last_act[bi] + tRC, last_data[bi]), horizon)
        t_act = jnp.where(is_conf, t_act_conf, t_act_miss)
        new_row_ready = jnp.where(is_hit, row_ready[bi], t_act + tRCD)

        slot_start = jnp.maximum(new_row_ready, bus_free)
        slot_end = slot_start + tBL
        bus_free = jnp.where(valid, slot_end, bus_free)

        open_row = jnp.where(valid, open_row.at[bi].set(r), open_row)
        row_ready = jnp.where(valid, row_ready.at[bi].set(new_row_ready), row_ready)
        last_data = jnp.where(valid, last_data.at[bi].set(slot_end), last_data)
        last_act = jnp.where(is_hit | ~valid, last_act, last_act.at[bi].set(t_act))
        return (open_row, row_ready, last_data, last_act, bus_free,
                hits + is_hit, misses + is_miss, confs + is_conf)

    carry = (
        state_ref[0, :], state_ref[1, :], state_ref[2, :], state_ref[3, :],
        scalars_ref[0, 0], scalars_ref[0, 1], scalars_ref[0, 2], scalars_ref[0, 3],
    )
    carry = jax.lax.fori_loop(0, block, body, carry)
    state_ref[0, :], state_ref[1, :], state_ref[2, :], state_ref[3, :] = carry[:4]
    scalars_ref[0, 0] = carry[4]
    scalars_ref[0, 1] = carry[5]
    scalars_ref[0, 2] = carry[6]
    scalars_ref[0, 3] = carry[7]

    @pl.when(step == n_blocks - 1)
    def _finalize():
        out = jnp.zeros((8,), dtype=jnp.int32)
        out = out.at[0].set(scalars_ref[0, 0] + tCL)  # total cycles
        out = out.at[1].set(scalars_ref[0, 1])
        out = out.at[2].set(scalars_ref[0, 2])
        out = out.at[3].set(scalars_ref[0, 3])
        out_ref[0, :] = out


@functools.partial(
    jax.jit,
    static_argnames=("nbanks", "tCL", "tRCD", "tRP", "tRC", "tBL",
                     "lookahead", "page_open", "block", "interpret"),
)
def dram_timing_pallas_batch(
    bank: jnp.ndarray,
    row: jnp.ndarray,
    *,
    nbanks: int,
    tCL: int,
    tRCD: int,
    tRP: int,
    tRC: int,
    tBL: int,
    lookahead: int,
    page_open: bool = True,
    block: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched kernel entry: bank/row are [B, L] with L a multiple of
    `block` and padding requests marked bank == -1.  Returns int32[B, 4]:
    per-trace (total_cycles, hits, misses, conflicts) from ONE dispatch.

    ``page_open=False`` compiles the closed-page variant (every request
    activates; no conflicts) — a trace-time branch, zero cost in-kernel.
    """
    assert nbanks <= STATE_BANKS_PAD
    assert bank.ndim == 2, "batched kernel expects [B, L] request arrays"
    b_sz, n = bank.shape
    assert n % block == 0, "pad the trace to a multiple of the block size"
    n_blocks = n // block
    kernel = functools.partial(
        _kernel, nbanks=nbanks, tCL=tCL, tRCD=tRCD, tRP=tRP, tRC=tRC,
        tBL=tBL, lookahead=lookahead, page_open=page_open, block=block,
        n_blocks=n_blocks,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b_sz, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda b, i: (b, i)),
            pl.BlockSpec((1, block), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, 8), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((b_sz, 8), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((4, STATE_BANKS_PAD), jnp.int32),
            pltpu.VMEM((1, 8), jnp.int32),
        ],
        interpret=interpret,
    )(bank, row)
    return out[:, :4]


def dram_timing_pallas(
    bank: jnp.ndarray,
    row: jnp.ndarray,
    *,
    nbanks: int,
    tCL: int,
    tRCD: int,
    tRP: int,
    tRC: int,
    tBL: int,
    lookahead: int,
    page_open: bool = True,
    block: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-trace entry (batch of one): returns int32[4]:
    (total_cycles, hits, misses, conflicts).

    bank/row must be pre-padded to a multiple of `block` with bank == -1.
    """
    out = dram_timing_pallas_batch(
        bank.reshape(1, -1), row.reshape(1, -1), nbanks=nbanks, tCL=tCL,
        tRCD=tRCD, tRP=tRP, tRC=tRC, tBL=tBL, lookahead=lookahead,
        page_open=page_open, block=block, interpret=interpret,
    )
    return out[0]
