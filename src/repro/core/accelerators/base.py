"""Shared machinery for accelerator models.

Semantic execution runs host-side in numpy (this mirrors the paper's C++
simulation environment: trace generation is itself an offline preprocessing
step), while DRAM timing runs through the JAX engine / Pallas kernel.

Timing is batched: ``simulate_phased`` collects every (phase, channel)
trace, dispatches them through :func:`repro.core.engine.simulate_batch` in
one grouped device call per length bucket, and scatters the per-trace
reports back into the per-phase barrier semantics (sum over phases of the
max over channels).  ``Accelerator.prepare`` exposes the semantic half on
its own so a sweep runner can batch timing *across* scenarios
(:class:`PendingRun` + ``finalize``).
"""
from __future__ import annotations

import abc
import dataclasses

import numpy as np

from repro.core.dram import DRAMConfig, dram_config
from repro.core.engine import (
    SCAN_CUTOFF,
    TimingReport,
    simulate_batch,
    simulate_sequential,
)
from repro.core import semexec
from repro.core.hostcache import ARTIFACTS, SEMANTICS
from repro.core.metrics import IterationStats, SimReport
from repro.core.trace import Trace, split_round_robin
from repro.graph.layout import (
    relabel_graph,
    relabel_values,
    undo_relabel,
    validate_interval_scale,
    validate_reorder,
)
from repro.graph.problems import Problem
from repro.graph.structure import Graph

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    """Accelerator-model configuration.

    interval_size: vertices per interval (the scaled BRAM capacity).
    n_pes: processing elements (ForeGraph) / channels (HitGraph, ThunderGP).
    optimizations: which of the accelerator's optimizations are on.  "all"
      enables every optimization the accelerator proposes (paper default).
    engine: DRAM engine selection ("auto" | "scan" | "fast").
    reorder: vertex reordering applied before partitioning
      ("identity" | "degree" | "random" | "bfs" — repro.graph.layout);
      results are mapped back to original ids, so semantics are unchanged.
    interval_scale: power-of-two multiplier on ``interval_size`` (the
      partition-granularity sweep axis; ``effective_interval`` is the
      product the partitioners actually see).
    semexec: semantic execution engine ("numpy" | "device") — where the
      per-iteration graph semantics run (repro.core.semexec).  "device"
      falls back to numpy (with a warning) for combos without a device
      formulation; the resolved engine is recorded in the run layout.
    """

    interval_size: int = 16384
    n_pes: int = 1
    optimizations: frozenset = frozenset({"all"})
    engine: str = "auto"
    max_iters: int = 4000
    scan_cutoff: int = SCAN_CUTOFF
    reorder: str = "identity"
    interval_scale: int = 1
    semexec: str = "numpy"

    def __post_init__(self):
        validate_reorder(self.reorder)
        validate_interval_scale(self.interval_scale)
        semexec.validate_engine(self.semexec)

    @property
    def effective_interval(self) -> int:
        """The interval size the partitioners see: base size x scale."""
        return self.interval_size * self.interval_scale

    def has(self, opt: str) -> bool:
        return "all" in self.optimizations or opt in self.optimizations

    # Fields that only affect DRAM timing, never the semantic execution;
    # every OTHER field (including ones added later) splits the semantic
    # cache, so a new semantics-relevant knob can never alias stale entries.
    _TIMING_ONLY_FIELDS = ("engine", "scan_cutoff")
    # Fields resolved per (accelerator, problem) before execution; prepare
    # appends the RESOLVED value to the semantic cache key instead, so a
    # requested "device" that falls back to numpy shares the numpy entry.
    _RESOLVED_FIELDS = ("semexec",)

    def semantic_key(self) -> tuple:
        """The config fields that determine a semantic execution (values,
        iterations, traces) — everything except the DRAM timing knobs and
        the per-problem resolved fields (appended post-resolution)."""
        key = []
        for f in dataclasses.fields(self):
            if f.name in self._TIMING_ONLY_FIELDS + self._RESOLVED_FIELDS:
                continue
            v = getattr(self, f.name)
            key.append(tuple(sorted(v)) if isinstance(v, frozenset) else v)
        return tuple(key)


@dataclasses.dataclass
class PhasedTrace:
    """Traces organised as [phase][channel]; phases are barriers (an
    iteration, or a scatter/gather phase within one)."""

    phases: list[list[Trace]] = dataclasses.field(default_factory=list)

    def add_phase(self, channel_traces: list[Trace]):
        if any(t.n for t in channel_traces):
            self.phases.append(channel_traces)

    def flatten(self) -> tuple[list[Trace], list[int]]:
        """The non-empty traces in (phase, channel) order, with each one's
        phase index — the batch the timing engine dispatches at once."""
        traces: list[Trace] = []
        phase_of: list[int] = []
        for pi, channel_traces in enumerate(self.phases):
            for tr in channel_traces:
                if tr.n:
                    traces.append(tr)
                    phase_of.append(pi)
        return traces, phase_of


def _assemble_phased(
    pt: PhasedTrace, phase_of: list[int], reports: list[TimingReport],
    cfg: DRAMConfig,
) -> TimingReport:
    """Scatter per-trace reports back into the barrier semantics: time =
    sum over phases of (max over that phase's channels); stats summed."""
    total = TimingReport.zero()
    phase_time = np.zeros(len(pt.phases), dtype=np.float64)
    for pi, r in zip(phase_of, reports):
        phase_time[pi] = max(phase_time[pi], r.time_ns)
        total.hits += r.hits
        total.misses += r.misses
        total.conflicts += r.conflicts
        total.bytes_total += r.bytes_total
        total.bytes_read += r.bytes_read
        total.bytes_written += r.bytes_written
        total.requests += r.requests
    time_ns = float(sum(phase_time.tolist()))
    total.time_ns = time_ns
    total.cycles = int(time_ns / cfg.tCK_ns) if time_ns else 0
    # actual channels used: the widest phase, counting non-empty traces only
    # (same denominator as simulate_dram).
    total.channels_used = max(
        (sum(1 for t in p if t.n) for p in pt.phases), default=0
    )
    peak = time_ns * cfg.bw_per_channel * max(total.channels_used, 1)
    total.bw_utilization = total.bytes_total / max(peak, 1e-9)
    return total


def expand_pseudo_channels(
    pt: PhasedTrace, cfg: DRAMConfig
) -> tuple[PhasedTrace, DRAMConfig]:
    """Resolve HBM pseudo-channel mode at the trace level: each channel
    trace is dealt across two pseudo-channels (lazy strided split at the
    mapping's channel-interleave granularity) and the config becomes the
    per-pseudo-channel view (half bus width, half banks).  Identity when
    the mode is off.  After expansion, "channels" everywhere downstream
    (phase max, channels_used, bw denominator) means pseudo-channels."""
    if not cfg.pseudo_channels:
        return pt, cfg
    g = cfg.mapping.channel_lines
    out = PhasedTrace()
    for channel_traces in pt.phases:
        # append directly: a non-empty phase stays non-empty after the
        # deal, and phase alignment must be preserved exactly
        out.phases.append(
            [pc for tr in channel_traces for pc in split_round_robin(tr, 2, g)]
        )
    return out, cfg.pseudo_channel_view()


def simulate_phased(
    pt: PhasedTrace, cfg: DRAMConfig, accel_cfg: AccelConfig,
    batched: bool = True,
) -> TimingReport:
    """Time = sum over phases of (max over channels); stats summed.

    ``batched=True`` (default) collects all phase/channel traces into one
    grouped dispatch; ``batched=False`` keeps the historical one-dispatch-
    per-trace path.  Both produce identical reports.
    """
    pt, cfg = expand_pseudo_channels(pt, cfg)
    traces, phase_of = pt.flatten()
    if batched:
        reports = simulate_batch(traces, cfg, engine=accel_cfg.engine,
                                 scan_cutoff=accel_cfg.scan_cutoff)
    else:
        reports = simulate_sequential(traces, cfg, accel_cfg.engine,
                                      accel_cfg.scan_cutoff)
    return _assemble_phased(pt, phase_of, reports, cfg)


@dataclasses.dataclass
class PendingRun:
    """A completed semantic execution awaiting DRAM timing.

    Produced by ``Accelerator.prepare``; ``traces()`` exposes the flat
    trace list so callers (e.g. the sweep runner's batch mode) can time
    traces from many runs in one grouped dispatch, then ``finalize`` each
    run with its slice of per-trace reports.
    """

    accelerator: str
    graph: str
    problem: str
    dram: DRAMConfig
    config: AccelConfig
    n: int
    m: int
    values: np.ndarray
    iterations: int
    pt: PhasedTrace
    stats: list[IterationStats]
    # layout record: reorder, interval_scale, effective_interval (the
    # interval the partitioner actually used) and partition balance metrics
    layout: dict = dataclasses.field(default_factory=dict)

    def traces(self) -> list[Trace]:
        return self.pt.flatten()[0]

    def finalize(self, reports: list[TimingReport] | None = None) -> SimReport:
        """Assemble the SimReport; ``reports`` must match ``traces()``
        one-to-one (omitted: simulate here, batched)."""
        traces, phase_of = self.pt.flatten()
        if reports is None:
            reports = simulate_batch(traces, self.dram, engine=self.config.engine,
                                     scan_cutoff=self.config.scan_cutoff)
        assert len(reports) == len(traces)
        timing = _assemble_phased(self.pt, phase_of, reports, self.dram)
        return SimReport(
            accelerator=self.accelerator,
            graph=self.graph,
            problem=self.problem,
            dram=self.dram.name,
            n=self.n,
            m=self.m,
            timing=timing,
            iterations=self.iterations,
            per_iteration=self.stats,
            values=self.values,
            layout=self.layout,
        )


class Accelerator(abc.ABC):
    """Base accelerator model.

    Subclasses implement ``_execute`` which performs the semantic iteration
    under the accelerator's scheme and fills a PhasedTrace + IterationStats,
    plus a small ``extras`` dict (effective interval, partition balance).
    """

    name: str = "base"
    default_dram: str = "default"
    supports_weights: bool = False
    supports_multichannel: bool = False

    def __init__(self, config: AccelConfig | None = None):
        self.config = config or AccelConfig()

    @abc.abstractmethod
    def _execute(
        self, g: Graph, problem: Problem, root: int,
        init: np.ndarray | None = None, engine: str = "numpy",
    ) -> tuple[np.ndarray, int, PhasedTrace, list[IterationStats], dict]:
        """``init`` overrides ``problem.init_values`` — the layout layer
        passes the original-space initial values carried through the vertex
        relabeling, so per-vertex payloads (SpMV's x vector, WCC's id
        labels) follow their vertices instead of their slots.  ``engine``
        is the RESOLVED semantic engine ("numpy" | "device") — callers go
        through ``prepare``, which resolves ``config.semexec``."""
        ...

    def prepare(
        self,
        g: Graph,
        problem: Problem,
        root: int = 0,
        dram: DRAMConfig | str | None = None,
    ) -> PendingRun:
        """Run the semantic half (trace assembly) only; the returned
        :class:`PendingRun` carries everything ``finalize`` needs once the
        DRAM timing reports exist.

        Both halves of the host preprocessing are cached per process: the
        prepared (symmetrised/weighted) graph by content fingerprint, and
        the whole semantic execution by (graph, problem, root, semantic
        config) — it is DRAM-independent, so a DDR3/DDR4/HBM sweep of one
        scenario assembles traces once.

        The layout axis resolves here: a non-identity ``config.reorder``
        relabels the prepared graph (and the root) before ``_execute`` and
        maps the final values back to original ids afterwards, so callers
        compare against ``reference_solve`` unchanged.  The relabeled graph
        carries its own content fingerprint, so reordered partition indices
        and semantic executions cache independently of the identity layout."""
        if problem.needs_weights and not self.supports_weights:
            raise ValueError(f"{self.name} does not support weighted problems")
        if isinstance(dram, str):
            dram = dram_config(dram)
        dram = dram or dram_config(self.default_dram)
        gp = ARTIFACTS.get_or_build(
            (g.fingerprint, "prepared", problem.name),
            lambda: problem.prepare_graph(g),
        )
        perm = None
        gx, root_x = gp, root
        if self.config.reorder != "identity":
            gx, perm = relabel_graph(gp, self.config.reorder)
            root_x = int(perm[root])
        engine = semexec.resolve_engine(self.name, problem.name,
                                        self.config.semexec)

        def execute():
            # per-vertex initial payloads (SpMV's x, WCC's labels) must
            # follow their vertices through the relabeling; built inside
            # the cache miss so a SEMANTICS hit pays no O(n) init work
            init = None
            if perm is not None:
                init = relabel_values(problem.init_values(gp, root), perm)
            return self._execute(gx, problem, root_x, init, engine)

        values, iters, pt, stats, extras = SEMANTICS.get_or_build(
            (gx.fingerprint, self.name, problem.name, root_x,
             self.config.semantic_key(), engine),
            execute,
        )
        # hand out copies of the mutable pieces: a caller mutating
        # report.values, an IterationStats or a balance dict must not
        # corrupt the cached execution (the PhasedTrace is shared — trace
        # nodes are immutable); undo_relabel's gather already allocates
        stats = [dataclasses.replace(s) for s in stats]
        if perm is not None:
            values = undo_relabel(values, perm, problem.name)
        else:
            values = values.copy()
        layout = dict(reorder=self.config.reorder,
                      interval_scale=self.config.interval_scale,
                      engine=engine,
                      **{k: dict(v) if isinstance(v, dict) else v
                         for k, v in extras.items()})
        # pseudo-channel mode resolves here, so PendingRun.traces() and
        # PendingRun.dram are consistent for external batchers (the sweep
        # runner times traces() against dram directly)
        pt, dram = expand_pseudo_channels(pt, dram)
        return PendingRun(
            accelerator=self.name,
            graph=g.name,
            problem=problem.name,
            dram=dram,
            config=self.config,
            n=gp.n,
            m=gp.m,
            values=values,
            iterations=iters,
            pt=pt,
            stats=stats,
            layout=layout,
        )

    def run(
        self,
        g: Graph,
        problem: Problem,
        root: int = 0,
        dram: DRAMConfig | str | None = None,
    ) -> SimReport:
        return self.prepare(g, problem, root=root, dram=dram).finalize()


def run_accelerator(
    name: str,
    g: Graph,
    problem: Problem,
    root: int = 0,
    dram: str | DRAMConfig | None = None,
    config: AccelConfig | None = None,
) -> SimReport:
    from repro.core.accelerators import ACCELERATORS

    cls = ACCELERATORS[name]
    return cls(config).run(g, problem, root=root, dram=dram)
