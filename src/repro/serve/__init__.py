"""Serving: KV/SSM cache management, prefill/decode steps, batched engine."""
