"""Request-trace abstractions and DRAM engine tests."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dram import DRAM_CONFIGS, dram_config
from repro.core.engine import (
    classify_fast,
    decode,
    simulate_channel_fast,
    simulate_channel_scan,
)
from repro.core.memory_layout import MemoryLayout
from repro.core.trace import (
    Trace,
    coalesce,
    concat,
    proportional_interleave,
    random_read,
    round_robin,
    seq_read,
    seq_write,
)


def test_seq_read_lines():
    t = seq_read(0, 256)
    assert t.n == 4  # 256 B = 4 lines
    assert not t.is_write.any()
    t = seq_read(60, 8)  # straddles a line boundary
    assert t.n == 2


def test_coalesce_merges_adjacent_only():
    t = Trace(np.array([0, 0, 1, 0]), np.zeros(4, dtype=bool))
    c = coalesce(t)
    assert c.lines.tolist() == [0, 1, 0]  # non-adjacent duplicate kept


def test_random_read_coalesces_same_line():
    # 16 int32 indices in the same cache line
    t = random_read(0, np.arange(16), 4)
    assert t.n == 1


def test_round_robin_interleaves():
    a = Trace(np.array([1, 2, 3]), np.zeros(3, dtype=bool))
    b = Trace(np.array([10, 20, 30]), np.zeros(3, dtype=bool))
    rr = round_robin(a, b)
    assert rr.lines.tolist() == [1, 10, 2, 20, 3, 30]


def test_proportional_interleave_preserves_order_and_length():
    a = Trace(np.arange(100), np.zeros(100, dtype=bool))
    b = Trace(np.arange(1000, 1010), np.ones(10, dtype=bool))
    m = proportional_interleave(a, b)
    assert m.n == 110
    # order within each stream preserved
    assert np.all(np.diff(m.lines[~m.is_write]) > 0)
    assert np.all(np.diff(m.lines[m.is_write]) > 0)


# ---------------- combinator edge cases ----------------


def test_round_robin_unequal_lengths():
    a = Trace(np.array([1, 2, 3, 4, 5]), np.zeros(5, dtype=bool))
    b = Trace(np.array([10, 20]), np.zeros(2, dtype=bool))
    rr = round_robin(a, b)
    # 1:1 merge while both streams last; the longer stream's tail follows
    assert rr.lines.tolist() == [1, 10, 2, 20, 3, 4, 5]


def test_round_robin_single_stream_is_identity():
    a = Trace(np.array([7, 3, 9]), np.array([False, True, False]))
    rr = round_robin(a)
    assert rr.lines.tolist() == [7, 3, 9]
    assert rr.is_write.tolist() == [False, True, False]


def test_round_robin_drops_empty_streams():
    a = Trace(np.array([1, 2]), np.zeros(2, dtype=bool))
    rr = round_robin(Trace.empty(), a, Trace.empty())
    assert rr.lines.tolist() == [1, 2]
    assert round_robin(Trace.empty(), Trace.empty()).n == 0


def test_proportional_interleave_single_stream_is_identity():
    a = Trace(np.array([5, 1, 8, 2]), np.zeros(4, dtype=bool))
    m = proportional_interleave(a)
    assert m.lines.tolist() == [5, 1, 8, 2]


def test_proportional_interleave_empty_streams():
    a = Trace(np.arange(10), np.zeros(10, dtype=bool))
    m = proportional_interleave(Trace.empty(), a)
    assert m.lines.tolist() == list(range(10))
    assert proportional_interleave(Trace.empty()).n == 0


def test_coalesce_does_not_merge_across_read_write_boundary():
    # same line, but a read followed by a write (or vice versa) must both
    # survive: the filter abstraction merges only same-kind adjacency
    t = Trace(np.array([4, 4, 4, 4]), np.array([False, True, True, False]))
    c = coalesce(t)
    assert c.lines.tolist() == [4, 4, 4]
    assert c.is_write.tolist() == [False, True, False]


def test_coalesce_empty_and_single():
    assert coalesce(Trace.empty()).n == 0
    one = Trace(np.array([3]), np.ones(1, dtype=bool))
    assert coalesce(one).lines.tolist() == [3]


def test_memory_layout_rows_do_not_overlap():
    lay = MemoryLayout()
    a = lay.alloc("a", 100)
    b = lay.alloc("b", 5000)
    c = lay.alloc("c", 1)
    assert a % 8192 == 0 and b % 8192 == 0 and c % 8192 == 0
    assert len({a, b, c}) == 3


# ---------------- engine ----------------


def test_sequential_stream_is_row_hits():
    cfg = dram_config("default")
    t = seq_read(0, 8192)  # exactly one row
    r = simulate_channel_scan(t, cfg)
    assert r.misses == 1  # first touch activates
    assert r.hits == r.requests - 1
    assert r.conflicts == 0


def test_row_ping_pong_is_conflicts():
    cfg = dram_config("default")
    # two addresses in the same bank, different rows: alternate
    lpr, nb = cfg.lines_per_row, cfg.nbanks
    line_a = 0  # bank 0 row 0
    line_b = lpr * nb  # bank 0 row 1
    lines = np.array([line_a, line_b] * 50)
    t = Trace(lines, np.zeros(100, dtype=bool))
    r = simulate_channel_scan(t, cfg)
    assert r.conflicts == 99 and r.misses == 1
    # conflict-bound stream is much slower than a sequential one
    seq = simulate_channel_scan(seq_read(0, 6400), cfg)
    assert r.time_ns > 3 * seq.time_ns


def test_bandwidth_utilization_near_peak_for_streaming():
    cfg = dram_config("default")
    t = seq_read(0, 4 << 20)  # 4 MiB stream
    r = simulate_channel_scan(t, cfg)
    assert r.bw_utilization > 0.85  # streaming should approach peak BW


def test_hbm_conflicts_cost_more_than_ddr4():
    """Insight 6 mechanics: HBM's smaller row buffer -> more row switches
    on the same access pattern."""
    ddr4 = dram_config("default")
    hbm = dram_config("hbm")
    rng = np.random.default_rng(0)
    lines = rng.integers(0, 1 << 16, size=4096)
    t = Trace(lines, np.zeros(4096, dtype=bool))
    r4 = simulate_channel_scan(t, ddr4)
    rh = simulate_channel_scan(t, hbm)
    assert rh.conflicts >= r4.conflicts * 0.9
    assert rh.time_ns > r4.time_ns * 0.9


def test_scan_and_fast_classification_agree():
    cfg = dram_config("default")
    rng = np.random.default_rng(1)
    lines = np.concatenate([
        np.arange(2048),
        rng.integers(0, 1 << 14, size=2048),
    ])
    t = Trace(lines, np.zeros(len(lines), dtype=bool))
    rs = simulate_channel_scan(t, cfg)
    rf = simulate_channel_fast(t, cfg)
    assert (rs.hits, rs.misses, rs.conflicts) == (rf.hits, rf.misses, rf.conflicts)
    # fast engine time within 2x of scan engine on mixed traces
    assert 0.5 < rf.time_ns / rs.time_ns < 2.0


@given(
    n_req=st.integers(1, 600),
    spread=st.integers(1, 1 << 18),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_engine_invariants(n_req, spread, seed):
    cfg = dram_config("default")
    rng = np.random.default_rng(seed)
    lines = rng.integers(0, spread, size=n_req)
    t = Trace(lines, rng.random(n_req) < 0.3)
    r = simulate_channel_scan(t, cfg)
    assert r.hits + r.misses + r.conflicts == n_req
    assert r.bytes_total == n_req * 64
    # time at least the bus-transfer lower bound, at most worst-case serial
    assert r.cycles >= n_req * cfg.tBL
    worst = n_req * (cfg.tRP + cfg.tRCD + cfg.tCL + cfg.tBL + cfg.tRC)
    assert r.cycles <= worst + cfg.tRC
    # classification agrees with the vectorised classifier
    bank, row = decode(t.lines, cfg)
    cls = classify_fast(bank, row, cfg.nbanks)
    assert (cls == 0).sum() == r.hits
    assert (cls == 1).sum() == r.misses
    assert (cls == 2).sum() == r.conflicts


def test_all_dram_configs_sane():
    for name, cfg in DRAM_CONFIGS.items():
        assert cfg.tBL >= 1 and cfg.nbanks >= 8
        assert cfg.lines_per_row >= 16
        t = seq_read(0, 64 * 1024)
        r = simulate_channel_scan(t, cfg)
        assert r.time_ns > 0
