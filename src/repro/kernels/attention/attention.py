"""Pallas TPU kernel: blocked causal flash-attention forward (GQA).

The LM serving/training hot-spot.  TPU adaptation: q/k/v tiles stream
HBM->VMEM under an explicit BlockSpec grid; the kernel keeps the classic
flash running-max/running-sum state in VMEM scratch across the sequential
kv-block axis of the grid, so the S x S score matrix never materialises.

Grid: (batch*q_heads, q_blocks, kv_blocks) with the kv axis innermost
(sequential); block shapes are MXU-aligned (multiples of 128 on the lane
dim, head_dim padded to 128 by the caller via ops.py).

``ref.py`` is the pure-jnp oracle (same math as models/attention._sdpa);
tests sweep shapes/dtypes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q, block_k, n_kv_blocks, causal, scale):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    q = q_ref[0, :, :]  # (block_q, d)
    k = k_ref[0, :, :]  # (block_k, d)
    v = v_ref[0, :, :]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)

    if causal:
        q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]  # (block_q, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (block_q, block_k)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)

    acc = acc_ref[...] * alpha
    acc = acc + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(kb == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, :, :] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (BH, S, D) query, BH = batch * q_heads
    k: jnp.ndarray,  # (BH, S, D) keys already expanded to q_heads (GQA: repeat)
    v: jnp.ndarray,  # (BH, S, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    scale: float | None = None,  # 1/sqrt(true head_dim); D may be lane-padded
) -> jnp.ndarray:
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, "pad seq to the block size"
    n_q = s // block_q
    n_k = s // block_k
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
        causal=causal, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qb, kb: (b, qb, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qb, kb: (b, kb, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qb, kb: (b, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qb, kb: (b, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
