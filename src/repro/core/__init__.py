"""Core of the paper's contribution: a memory-access simulation environment
for graph processing accelerators (Dann, Ritter, Froening 2021).

The environment follows the paper's central observation: off-chip memory
access dominates graph-accelerator performance, so on-chip data flow need not
be simulated cycle-accurately.  Accelerator models therefore generate their
off-chip *request traces* (type, address, volume, ordering) which are played
through a DRAM timing model (a vectorised, TPU-native re-design of
Ramulator's bank state machines — see DESIGN.md for the hardware-adaptation
notes).
"""
from repro.core.dram import (
    AddressMapping,
    DRAMConfig,
    DRAM_CONFIGS,
    MAPPING_SCHEMES,
    PAGE_POLICIES,
    dram_config,
)
from repro.core.trace import (
    Trace,
    seq_read,
    seq_write,
    random_read,
    random_write,
    coalesce,
    concat,
    round_robin,
    proportional_interleave,
    split_round_robin,
)
from repro.core.engine import (
    TimingReport,
    TraceBatch,
    dispatch_stats,
    reset_dispatch_stats,
    select_engine,
    simulate_batch,
    simulate_dram,
    simulate_many,
    simulate_sequential,
)
from repro.core.metrics import SimReport
from repro.core.memory_layout import MemoryLayout

__all__ = [
    "AddressMapping",
    "DRAMConfig",
    "DRAM_CONFIGS",
    "MAPPING_SCHEMES",
    "PAGE_POLICIES",
    "dram_config",
    "Trace",
    "split_round_robin",
    "seq_read",
    "seq_write",
    "random_read",
    "random_write",
    "coalesce",
    "concat",
    "round_robin",
    "proportional_interleave",
    "simulate_dram",
    "simulate_batch",
    "simulate_many",
    "simulate_sequential",
    "select_engine",
    "dispatch_stats",
    "reset_dispatch_stats",
    "TimingReport",
    "TraceBatch",
    "SimReport",
    "MemoryLayout",
]
