"""Jitted serving steps: prefill (prompt -> cache) and decode (one token).

``serve_step`` (decode) lowers ONE new token against a cache of seq_len —
this is what the assigned ``decode_32k`` / ``long_500k`` shapes measure.
Caches are sequence-sharded over the "model" axis (context-parallel decode;
see distributed/sharding.py) and batch-sharded over the DP axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model


def make_prefill_step(model: Model):
    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill


def make_decode_step(model: Model):
    def decode(params, tokens, cache, pos):
        logits, cache = model.decode_step(params, tokens, cache, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return decode


def jit_serve_steps(model: Model, mesh, batch: int, max_seq: int,
                    batch_abstract=None):
    """jit prefill + decode with production shardings.

    ``batch_abstract``: optional pytree (ShapeDtypeStructs or arrays) of the
    prefill batch, used to pin its shardings; defaults to unspecified.
    Returns (prefill_fn, decode_fn, cache_shardings)."""
    from repro.distributed import sharding as shd
    from repro.distributed.context import ActivationPolicy, activation_policy
    from jax.sharding import PartitionSpec as P, NamedSharding

    pspecs = shd.param_specs(model.init_abstract(), mesh)
    p_sh = shd.shardings(mesh, pspecs)
    cache_abs = jax.eval_shape(lambda: model.init_cache(batch, max_seq))
    c_sh = shd.shardings(mesh, shd.cache_specs(mesh, cache_abs))
    b = shd.effective_batch_axes(mesh, batch) or None
    tok_sh = NamedSharding(mesh, P(b, None))
    pol = ActivationPolicy(mesh, b)

    prefill_fn = make_prefill_step(model)
    decode_fn = make_decode_step(model)

    def prefill_pol(params, batch_, cache):
        with activation_policy(pol):
            return prefill_fn(params, batch_, cache)

    def decode_pol(params, tokens, cache, pos):
        with activation_policy(pol):
            return decode_fn(params, tokens, cache, pos)

    b_sh = (
        shd.shardings(mesh, shd.batch_specs(mesh, batch_abstract))
        if batch_abstract is not None
        else None
    )
    prefill = jax.jit(
        prefill_pol,
        in_shardings=(p_sh, b_sh, c_sh),
        out_shardings=(None, c_sh),
    )
    decode = jax.jit(
        decode_pol,
        in_shardings=(p_sh, tok_sh, c_sh, None),
        out_shardings=(tok_sh, None, c_sh),
        donate_argnums=(2,),
    )
    return prefill, decode, c_sh
