"""Public op: DRAM timing via the Pallas kernel (TPU) or scan oracle (CPU)."""
from __future__ import annotations

import numpy as np
import jax

from repro.core.dram import DRAMConfig
from repro.core.engine import decode
from repro.core.trace import Trace
from repro.kernels.dram_timing.dram_timing import dram_timing_pallas
from repro.kernels.dram_timing.ref import dram_timing_ref


def simulate_trace(
    trace: Trace,
    cfg: DRAMConfig,
    *,
    use_pallas: bool | None = None,
    block: int = 512,
    interpret: bool | None = None,
) -> dict:
    """Time a single-channel trace; returns cycles + row-buffer stats.

    ``use_pallas=None`` auto-selects: the Pallas kernel on TPU backends,
    the scan oracle elsewhere (interpret-mode Pallas is for tests)."""
    if trace.n == 0:
        return dict(cycles=0, hits=0, misses=0, conflicts=0)
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    bank, row = decode(trace.lines, cfg)
    t = cfg.timing_cycles()
    kw = dict(nbanks=cfg.nbanks, tCL=t["tCL"], tRCD=t["tRCD"], tRP=t["tRP"],
              tRC=t["tRC"], tBL=t["tBL"], lookahead=16 * t["tBL"])
    if use_pallas:
        pad = (-len(bank)) % block
        if pad:
            bank = np.concatenate([bank, np.full(pad, -1, dtype=bank.dtype)])
            row = np.concatenate([row, np.zeros(pad, dtype=row.dtype)])
        out = dram_timing_pallas(
            bank, row, block=block,
            interpret=(not on_tpu) if interpret is None else interpret, **kw,
        )
    else:
        out = dram_timing_ref(bank, row, **kw)
    out = np.asarray(out)
    return dict(cycles=int(out[0]), hits=int(out[1]), misses=int(out[2]),
                conflicts=int(out[3]))
