"""Model-level API: init / forward / loss / prefill / decode for every
assigned architecture, selected purely by its ArchConfig.

All functions are pure; ``Model`` is a thin namespace bound to a config.
Inputs are batch dicts:

  train/prefill: {"tokens": (B,S) i32, "labels": (B,S) i32,
                  ["enc_frames": (B,F,D)]  (whisper stub frontend),
                  ["img_embeds": (B,I,D)]  (vlm stub frontend)}
  decode:        tokens (B,1) i32 + cache + scalar position

The modality frontends are STUBS per the assignment: ``input_specs``
provides precomputed frame/patch embeddings at model width.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import transformer as tf
from repro.models.layers import (
    dtype_of,
    embed,
    embedding_params,
    rmsnorm,
    rmsnorm_params,
    softmax_xent,
    unembed,
)

LB_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-3
VOCAB_ALIGN = 256  # lcm(TP width, TPU lane) — vocab padded for sharding


def padded_vocab(vocab: int) -> int:
    return -(-vocab // VOCAB_ALIGN) * VOCAB_ALIGN


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: object  # ArchConfig

    # ---- structure ----

    @property
    def dtype(self):
        return dtype_of(self.cfg.dtype)

    @property
    def program(self) -> list[tf.LayerSpec]:
        return tf.layer_program(self.cfg)

    @property
    def enc_program(self) -> list[tf.LayerSpec]:
        return [tf.LayerSpec("attn_nc", "mlp")] * self.cfg.n_enc_layers

    @property
    def vocab_padded(self) -> int:
        return padded_vocab(self.cfg.vocab)

    def _stacked_blocks(self, key, program):
        """Init per-position params stacked over repeats."""
        period, repeats = tf.find_period(program)
        keys = jax.random.split(key, period * repeats)
        blocks = []
        for pos in range(period):
            per_rep = [
                tf.block_params(keys[pos * repeats + r], self.cfg, program[pos], self.dtype)
                for r in range(repeats)
            ]
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
        return blocks

    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_blocks, k_enc = jax.random.split(key, 3)
        params = {
            "embed": embedding_params(
                k_emb, self.vocab_padded, cfg.d_model, self.dtype, cfg.tie_embeddings
            ),
            "blocks": self._stacked_blocks(k_blocks, self.program),
            "final_norm": rmsnorm_params(cfg.d_model, self.dtype),
        }
        if cfg.n_enc_layers:
            params["enc"] = {
                "blocks": self._stacked_blocks(k_enc, self.enc_program),
                "final_norm": rmsnorm_params(cfg.d_model, self.dtype),
            }
        return params

    def init_abstract(self) -> dict:
        """ShapeDtypeStruct pytree of the parameters (no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # ---- forward (train / prefill) ----

    def _context(self, params, batch) -> dict:
        ctx = {}
        if self.cfg.n_enc_layers:
            frames = batch["enc_frames"].astype(self.dtype)
            enc_x, _ = tf.stack_forward(
                params["enc"]["blocks"], self.cfg, self.enc_program, frames, {},
                remat=self.cfg.remat,
            )
            ctx["kv_src"] = rmsnorm(params["enc"]["final_norm"], enc_x)
        elif self.cfg.cross_attn_every:
            ctx["kv_src"] = batch["img_embeds"].astype(self.dtype)
        return ctx

    def forward(self, params, batch) -> jnp.ndarray:
        """Logits (B, S, vocab_padded) in the compute dtype (vocab sharded)."""
        tokens = batch["tokens"]
        x = constrain(embed(params["embed"], tokens).astype(self.dtype), "btd")
        ctx = self._context(params, batch)
        x, _aux = tf.stack_forward(
            params["blocks"], self.cfg, self.program, x, ctx, remat=self.cfg.remat
        )
        x = rmsnorm(params["final_norm"], x)
        logits = constrain(unembed(params["embed"], x), "logits")
        return _mask_padded_vocab(logits, self.cfg.vocab)

    def loss(self, params, batch):
        """Mean next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
        tokens = batch["tokens"]
        x = constrain(embed(params["embed"], tokens).astype(self.dtype), "btd")
        ctx = self._context(params, batch)
        x, aux = tf.stack_forward(
            params["blocks"], self.cfg, self.program, x, ctx, remat=self.cfg.remat
        )
        x = rmsnorm(params["final_norm"], x)
        logits = constrain(unembed(params["embed"], x), "logits")
        logits = _mask_padded_vocab(logits, self.cfg.vocab)
        ce = softmax_xent(logits, batch["labels"], batch.get("mask"))
        loss = ce
        if self.cfg.n_experts:
            loss = loss + LB_LOSS_WEIGHT * aux["moe_lb_loss"] + Z_LOSS_WEIGHT * aux["moe_z_loss"]
        metrics = {"ce": ce, **aux}
        return loss, metrics

    # ---- serving ----

    def init_cache(self, batch: int, max_seq: int) -> dict:
        cache = {
            "blocks": tf.stack_cache_init(
                self.cfg, self.program, batch, max_seq, self.dtype
            )
        }
        if self.cfg.n_enc_layers:
            cache["kv_src"] = jnp.zeros(
                (batch, self.cfg.n_frames, self.cfg.d_model), dtype=self.dtype
            )
        elif self.cfg.cross_attn_every:
            cache["kv_src"] = jnp.zeros(
                (batch, self.cfg.n_img_tokens, self.cfg.d_model), dtype=self.dtype
            )
        return cache

    def prefill(self, params, batch, cache: dict):
        """Run the full prompt, fill the cache, return (last_logits, cache).

        Prompt K/V (and final SSM states) are produced by the full-sequence
        forward and merged into the pre-allocated cache in one shot."""
        tokens = batch["tokens"]
        ctx = self._context(params, batch)
        if "kv_src" in cache and "kv_src" in ctx:
            cache = dict(cache, kv_src=ctx["kv_src"])
        x = constrain(embed(params["embed"], tokens).astype(self.dtype), "btd")
        x, new_blocks = tf.stack_prefill(
            params["blocks"], self.cfg, self.program, x, cache["blocks"], ctx
        )
        cache = dict(cache, blocks=new_blocks)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x[:, -1:, :])
        return _mask_padded_vocab(logits, self.cfg.vocab), cache

    def decode_step(self, params, tokens, cache: dict, pos):
        """One token for the whole batch.  tokens: (B, 1); pos: scalar i32."""
        x = constrain(embed(params["embed"], tokens).astype(self.dtype), "btd")
        ctx = {}
        if "kv_src" in cache:
            ctx["kv_src"] = cache["kv_src"]
        x, new_blocks = tf.stack_decode(
            params["blocks"], self.cfg, self.program, x, cache["blocks"],
            jnp.asarray(pos, jnp.int32), ctx,
        )
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)
        return _mask_padded_vocab(logits, self.cfg.vocab), dict(cache, blocks=new_blocks)


def _mask_padded_vocab(logits, vocab: int):
    if logits.shape[-1] == vocab:
        return logits
    pad = logits.shape[-1] - vocab
    neg = jnp.full((pad,), -1e30, dtype=logits.dtype)
    bias = jnp.concatenate([jnp.zeros((vocab,), dtype=logits.dtype), neg])
    return logits + bias


