"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run result JSONs (results/dryrun vs results/dryrun_baseline)."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*", "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(recs, mesh="single"):
    lines = [
        "| arch | shape | step | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | MODEL/HLO flops | temp GiB/chip |",
        "|---|---|---|---:|---:|---:|---|---:|---:|",
    ]
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for (arch, shape, m), r in sorted(
        recs.items(), key=lambda kv: (kv[0][0], order.index(kv[0][1]))
    ):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | | | | *skipped: "
                         f"{r['reason'].split('(')[0].strip()}* | | |")
            continue
        rl = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {r['step_kind']} "
            f"| {rl['compute_s']*1e3:,.1f} | {rl['memory_s']*1e3:,.1f} "
            f"| {rl['collective_s']*1e3:,.1f} | **{rl['dominant']}** "
            f"| {r.get('useful_flops_ratio') or 0:.3f} "
            f"| {r['memory'].get('temp_bytes', 0)/2**30:.1f} |"
        )
    return "\n".join(lines)


def dryrun_summary(recs):
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    er = len(recs) - ok - sk
    compile_s = sum(r.get("compile_s", 0) for r in recs.values() if r["status"] == "ok")
    return ok, sk, er, compile_s


def compare_table(base, opt):
    """Baseline vs optimized for the three hillclimbed pairs."""
    pairs = [
        ("qwen2_moe_a2_7b", "train_4k"),
        ("arctic_480b", "train_4k"),
        ("qwen3_0_6b", "decode_32k"),
    ]
    lines = [
        "| pair | term | baseline | optimized | delta |",
        "|---|---|---:|---:|---:|",
    ]
    for arch, shape in pairs:
        b = base.get((arch, shape, "single"))
        o = opt.get((arch, shape, "single"))
        if not b or not o or b["status"] != "ok" or o["status"] != "ok":
            continue
        rows = [
            ("compute s", b["roofline"]["compute_s"], o["roofline"]["compute_s"]),
            ("memory s", b["roofline"]["memory_s"], o["roofline"]["memory_s"]),
            ("collective s", b["roofline"]["collective_s"], o["roofline"]["collective_s"]),
            ("HLO flops/dev", b["hlo_analysis"]["flops_per_device"],
             o["hlo_analysis"]["flops_per_device"]),
            ("traffic GiB/dev", b["hlo_analysis"]["bytes_per_device"] / 2**30,
             o["hlo_analysis"]["bytes_per_device"] / 2**30),
            ("coll GiB/dev", b["hlo_analysis"]["collective_bytes_per_device"] / 2**30,
             o["hlo_analysis"]["collective_bytes_per_device"] / 2**30),
        ]
        for name, bv, ov in rows:
            delta = (bv / ov) if ov else float("inf")
            lines.append(f"| {arch} x {shape} | {name} | {bv:,.3g} | {ov:,.3g} "
                         f"| {delta:,.2f}x |")
    return "\n".join(lines)


if __name__ == "__main__":
    opt = load("results/dryrun")
    base = load("results/dryrun_baseline")
    ok, sk, er, cs = dryrun_summary(opt)
    print(f"## generated tables\ncells: {ok} ok, {sk} skipped, {er} errors; "
          f"total compile {cs:.0f}s\n")
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "roofline"):
        print("### single-pod roofline (optimized)\n")
        print(roofline_table(opt, "single"))
        print("\n### multi-pod (2x16x16)\n")
        print(roofline_table(opt, "multi"))
    if which in ("all", "compare") and base:
        print("\n### before/after (hillclimbed pairs)\n")
        print(compare_table(base, opt))
