"""HTTP front of the sweep server: JSONL streaming, /stats, SIGTERM drain.

Endpoints (all local-loopback by default):

- ``POST /submit`` — body ``{"spec": <wire spec>}``; responds with a
  chunked ``application/x-ndjson`` stream of job events (see
  :mod:`repro.serve.protocol`).  The connection IS the subscription: a
  client that disconnects mid-stream cancels its job (results computed so
  far stay cached for everyone else).
- ``POST /search`` — body ``{"search": <wire search>}``; same streaming
  contract, but the job is an adaptive search
  (:mod:`repro.sweep.search`): the stream carries ``proposal`` /
  ``progress`` / ``row`` events as the loop explores, then a
  ``search_result`` event with the answer before ``done``.
- ``GET /stats`` — scheduler metrics snapshot (queue depth, cache-hit /
  in-flight-join / dedup counters, per-stage latency, worker utilization).
- ``GET /jobs/<id>`` — one job's progress snapshot.
- ``POST /jobs/<id>/cancel`` — cancel a job.
- ``GET /health`` — liveness + engine version (cache compatibility).
- ``POST /shutdown`` — programmatic drain (same path as SIGTERM).

Robustness is the scheduler's (timeout/retry/backoff via
:class:`repro.sweep.ExecutionPolicy`); this layer only adds transport:
each connection gets its own thread, streams never buffer more than one
event, and a SIGTERM drains gracefully — running work finishes and is
persisted, streams receive an ``interrupted`` event, then the process
exits.  Structured single-line JSON logs go to stderr.
"""
from __future__ import annotations

import json
import signal
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.core.engine import ENGINE_VERSION
from repro.serve.protocol import (
    ProtocolError,
    dump_event,
    search_from_wire,
    spec_from_wire,
)
from repro.serve.scheduler import TERMINAL_EVENTS, SweepScheduler
from repro.sweep.runner import ExecutionPolicy


def jlog(event: str, quiet: bool = False, **fields) -> None:
    """Structured log line: one JSON object per event, stderr."""
    if quiet:
        return
    rec = dict(ts=round(time.time(), 3), event=event, **fields)
    print(json.dumps(rec, separators=(",", ":")), file=sys.stderr, flush=True)


class SweepServer:
    """Owns a :class:`SweepScheduler` and its HTTP front."""

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        cache_dir: str | None = None,
        workers: int = 2,
        mode: str = "batch",
        policy: ExecutionPolicy | None = None,
        chunk_size: int = 4,
        trace_hashes: bool = False,
        quiet: bool = False,
        pool_factory=None,
        poison_threshold: int = 3,
        fault_plan=None,
        worker_deadline_s: float | None = 300.0,
        resume: bool = True,
    ):
        self.quiet = quiet
        self.scheduler = SweepScheduler(
            cache_dir=cache_dir, workers=workers, mode=mode, policy=policy,
            chunk_size=chunk_size, trace_hashes=trace_hashes,
            log=self._log, pool_factory=pool_factory,
            poison_threshold=poison_threshold, fault_plan=fault_plan,
            worker_deadline_s=worker_deadline_s, resume=resume,
        )
        self.httpd = ThreadingHTTPServer((host, port), _Handler)
        self.httpd.daemon_threads = True
        self.httpd.app = self  # type: ignore[attr-defined]
        self.host, self.port = self.httpd.server_address[:2]
        self._serve_thread: threading.Thread | None = None
        self._stopped = threading.Event()
        self._streams = 0
        self._streams_cv = threading.Condition()

    def _log(self, event: str, **fields) -> None:
        jlog(event, quiet=self.quiet, **fields)

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def start(self) -> "SweepServer":
        self._serve_thread = threading.Thread(
            target=self.httpd.serve_forever, name="sweep-http", daemon=True)
        self._serve_thread.start()
        self._log("ready", host=self.host, port=self.port,
                  engine_version=ENGINE_VERSION)
        return self

    def install_signal_handlers(self) -> None:
        """SIGTERM/SIGINT -> graceful drain (call from the main thread)."""
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, self._on_signal)

    def _on_signal(self, signum, frame) -> None:
        self._log("signal", signum=int(signum))
        threading.Thread(target=self.shutdown, name="sweep-drain",
                         daemon=True).start()

    def shutdown(self) -> None:
        """Drain and stop: reject new jobs, finish running chunks (rows
        persisted + streamed), end open streams, close the listener."""
        if self._stopped.is_set():
            return
        self.scheduler.drain()
        # open streams end on their interrupted/done events; give them a
        # moment to flush their final chunk before the listener dies
        with self._streams_cv:
            self._streams_cv.wait_for(lambda: self._streams == 0,
                                      timeout=5.0)
        self.httpd.shutdown()
        self.httpd.server_close()
        self._stopped.set()
        self._log("stopped")

    def wait(self) -> None:
        """Block until the server has fully stopped (after a drain)."""
        while not self._stopped.wait(timeout=0.5):
            pass

    def close(self) -> None:
        """Hard stop for tests (no drain semantics)."""
        if self._stopped.is_set():
            return
        self.scheduler.close()
        self.httpd.shutdown()
        self.httpd.server_close()
        self._stopped.set()

    def _stream_opened(self) -> None:
        with self._streams_cv:
            self._streams += 1

    def _stream_closed(self) -> None:
        with self._streams_cv:
            self._streams -= 1
            self._streams_cv.notify_all()


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    @property
    def app(self) -> SweepServer:
        return self.server.app  # type: ignore[attr-defined]

    def log_message(self, fmt, *args):  # route through structured logs
        self.app._log("http", request=fmt % args)

    # ---- helpers -----------------------------------------------------------

    def _json(self, code: int, payload: dict) -> None:
        body = json.dumps(payload, separators=(",", ":")).encode() + b"\n"
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b"{}"
        try:
            body = json.loads(raw or b"{}")
        except json.JSONDecodeError as e:
            raise ProtocolError(f"request body is not JSON: {e}")
        if not isinstance(body, dict):
            raise ProtocolError("request body must be a JSON object")
        return body

    def _chunk(self, data: bytes) -> None:
        self.wfile.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
        self.wfile.flush()

    # ---- routes ------------------------------------------------------------

    def do_GET(self) -> None:
        if self.path == "/health":
            self._json(200, dict(status="ok", engine_version=ENGINE_VERSION,
                                 draining=self.app.scheduler.stats()["draining"]))
        elif self.path == "/stats":
            self._json(200, self.app.scheduler.stats())
        elif self.path.startswith("/jobs/"):
            job = self.app.scheduler.get_job(self.path[len("/jobs/"):])
            if job is None:
                self._json(404, dict(error="no such job"))
            else:
                self._json(200, job.status())
        else:
            self._json(404, dict(error=f"no such endpoint {self.path!r}"))

    def do_POST(self) -> None:
        try:
            if self.path == "/submit":
                self._submit()
            elif self.path == "/search":
                self._search()
            elif self.path.startswith("/jobs/") and self.path.endswith("/cancel"):
                job_id = self.path[len("/jobs/"):-len("/cancel")]
                ok = self.app.scheduler.cancel(job_id)
                self._json(200 if ok else 409,
                           dict(cancelled=ok, job_id=job_id))
            elif self.path == "/shutdown":
                self._json(200, dict(ok=True, draining=True))
                threading.Thread(target=self.app.shutdown,
                                 name="sweep-drain", daemon=True).start()
            else:
                self._json(404, dict(error=f"no such endpoint {self.path!r}"))
        except ProtocolError as e:
            self._json(400, dict(error=str(e)))

    def _submit(self) -> None:
        body = self._read_body()
        if "spec" not in body:
            raise ProtocolError("submit body needs a 'spec' field")
        spec = spec_from_wire(body["spec"])
        try:
            job = self.app.scheduler.submit(spec)
        except ValueError as e:  # bad axis values -> client error
            self._json(400, dict(error=str(e)))
            return
        except RuntimeError as e:  # draining
            self._json(503, dict(error=str(e)))
            return
        self._stream_job(job)

    def _search(self) -> None:
        body = self._read_body()
        if "search" not in body:
            raise ProtocolError("search body needs a 'search' field")
        sspec = search_from_wire(body["search"])
        try:
            job = self.app.scheduler.submit_search(sspec)
        except ValueError as e:
            self._json(400, dict(error=str(e)))
            return
        except RuntimeError as e:  # draining
            self._json(503, dict(error=str(e)))
            return
        self._stream_job(job)

    def _stream_job(self, job) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()
        self.app._stream_opened()
        try:
            while True:
                event = job.events.get()
                self._chunk(dump_event(event))
                if event["type"] in TERMINAL_EVENTS:
                    break
            self._chunk(b"")  # terminating chunk
        except (BrokenPipeError, ConnectionResetError):
            # the stream is the subscription: a vanished client cancels
            # its job (completed scenarios stay cached)
            self.app.scheduler.cancel(job.id)
            self.close_connection = True
        finally:
            self.app._stream_closed()
