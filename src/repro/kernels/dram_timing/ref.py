"""Pure-jnp oracle for the dram_timing Pallas kernel: the lax.scan engine
from repro.core.engine (the simulation environment's ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import _scan_engine


def dram_timing_ref(bank, row, *, nbanks, tCL, tRCD, tRP, tRC, tBL, lookahead):
    """Returns int32[4]: (total_cycles, hits, misses, conflicts)."""
    cycles, hits, misses, conflicts = _scan_engine(
        jnp.asarray(bank), jnp.asarray(row), nbanks, tCL, tRCD, tRP, tRC, tBL,
        lookahead,
    )
    return jnp.stack([cycles, hits, misses, conflicts]).astype(jnp.int32)
