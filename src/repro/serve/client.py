"""Thin client for the sweep server.

:class:`ServeClient` talks plain HTTP/JSONL (stdlib only) to a local
:class:`repro.serve.server.SweepServer`::

    client = ServeClient("127.0.0.1:8731")
    result = client.run(spec)            # submit, stream, reassemble
    rows = result.rows                   # CLI-identical, expansion order

``submit()`` exposes the raw event stream for callers that want
incremental rows (events arrive in completion order, each tagged with its
expansion-order ``index``); ``run()`` collects a stream into a
:class:`JobResult` whose ``rows`` are reassembled into expansion order —
byte-identical to what ``python -m repro.sweep`` exports for the same
spec and cache state.
"""
from __future__ import annotations

import http.client
import json
import time

from repro.serve.protocol import (
    ProtocolError,
    parse_event,
    search_to_wire,
    spec_to_wire,
)
from repro.serve.scheduler import TERMINAL_EVENTS
from repro.sweep.search.loop import SearchSpec
from repro.sweep.spec import SweepSpec


class ServeError(RuntimeError):
    """Server-side rejection (bad spec, draining, unknown job...)."""


class JobResult:
    """A collected job stream."""

    def __init__(self, job_id: str, total: int, skipped: list,
                 events: list[dict], outcome: str):
        self.job_id = job_id
        self.total = total
        self.skipped = skipped
        self.events = events
        self.outcome = outcome  # done | cancelled | interrupted
        row_events = sorted((e for e in events if e["type"] == "row"),
                            key=lambda e: e["index"])
        self.row_events = row_events
        self.rows = [e["row"] for e in row_events]
        self.statuses = [e["status"] for e in row_events]

    def rows_with_status(self) -> list[dict]:
        """Rows with the status column in the CLI's ``--out`` position
        (right after ``label``), matching ``result_rows(with_status=True)``."""
        out = []
        for ev in self.row_events:
            row: dict = {}
            for k, v in ev["row"].items():
                row[k] = v
                if k == "label":
                    row["status"] = ev["status"]
            out.append(row)
        return out

    @property
    def n_cached(self) -> int:
        return sum(s == "cached" for s in self.statuses)

    @property
    def n_errors(self) -> int:
        return sum(s == "error" for s in self.statuses)

    @property
    def n_poisoned(self) -> int:
        """Error rows from the scheduler's poison circuit breaker (the
        scenario repeatedly killed its workers and was quarantined)."""
        return sum(bool(e.get("poison")) for e in self.row_events)


class SearchJobResult(JobResult):
    """A collected search-job stream: sweep-shaped rows for every probe,
    plus the search's answer (``result``, the
    :meth:`repro.sweep.search.SearchResult.to_dict` payload) and the
    per-round ``proposals`` (lists of scenario hashes)."""

    def __init__(self, job_id: str, total: int, skipped: list,
                 events: list[dict], outcome: str):
        super().__init__(job_id, total, skipped, events, outcome)
        self.result: dict | None = None
        self.proposals: list[list[str]] = []
        self.error: str | None = None
        for ev in events:
            if ev["type"] == "search_result":
                self.result = ev["result"]
            elif ev["type"] == "proposal":
                self.proposals.append(ev["hashes"])
            elif ev["type"] == "search_error":
                self.error = ev["error"]


class ServeClient:
    def __init__(self, address: str, timeout: float = 600.0):
        host, _, port = address.rpartition(":")
        self.host, self.port = host or "127.0.0.1", int(port)
        self.timeout = timeout

    def _connect(self) -> http.client.HTTPConnection:
        return http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)

    def _call(self, method: str, path: str, body: dict | None = None) -> dict:
        conn = self._connect()
        try:
            payload = json.dumps(body).encode() if body is not None else None
            conn.request(method, path, body=payload,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            data = json.loads(resp.read() or b"{}")
            if resp.status >= 400:
                raise ServeError(data.get("error", f"HTTP {resp.status}"))
            return data
        finally:
            conn.close()

    # ---- control-plane calls ----------------------------------------------

    def health(self) -> dict:
        return self._call("GET", "/health")

    def wait_ready(self, deadline_s: float = 30.0) -> dict:
        t0 = time.time()
        while True:
            try:
                return self.health()
            except (OSError, ServeError):
                if time.time() - t0 > deadline_s:
                    raise
                time.sleep(0.1)

    def stats(self) -> dict:
        return self._call("GET", "/stats")

    def job_status(self, job_id: str) -> dict:
        return self._call("GET", f"/jobs/{job_id}")

    def cancel(self, job_id: str) -> bool:
        try:
            return bool(self._call("POST", f"/jobs/{job_id}/cancel")["cancelled"])
        except ServeError:
            return False

    def shutdown(self) -> dict:
        return self._call("POST", "/shutdown")

    # ---- submission --------------------------------------------------------

    def submit(self, spec: SweepSpec):
        """Submit and yield events as they stream.  The generator's first
        event is the ``job`` header; it ends after a terminal event."""
        return self._post_stream("/submit", dict(spec=spec_to_wire(spec)))

    def submit_search(self, sspec: SearchSpec):
        """Submit an adaptive search and yield its events as they stream
        (``proposal`` / ``progress`` / ``row`` / ``search_result`` /
        terminal; see :mod:`repro.serve.protocol`)."""
        return self._post_stream("/search", dict(search=search_to_wire(sspec)))

    def _post_stream(self, path: str, body: dict):
        conn = self._connect()
        conn.request("POST", path, body=json.dumps(body).encode(),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status >= 400:
            err = json.loads(resp.read() or b"{}")
            conn.close()
            raise ServeError(err.get("error", f"HTTP {resp.status}"))

        def events():
            try:
                while True:
                    line = resp.readline()
                    if not line:
                        break
                    line = line.strip()
                    if not line:
                        continue
                    ev = parse_event(line)
                    yield ev
                    if ev["type"] in TERMINAL_EVENTS:
                        break
            finally:
                conn.close()

        return events()

    def run(self, spec: SweepSpec) -> JobResult:
        """Submit, stream to completion, reassemble rows in expansion
        order.  ``interrupted`` streams (server drained mid-job) return
        what completed — resubmitting resumes from the cache."""
        return self._collect(self.submit(spec), JobResult)

    def run_search(self, sspec: SearchSpec) -> SearchJobResult:
        """Submit an adaptive search, stream to completion.  The returned
        :class:`SearchJobResult` carries the probes' sweep-shaped rows
        and the search's answer dict; an ``interrupted`` stream (server
        drained) returns what ran — resubmitting warm-starts from the
        cache and continues the exploration."""
        return self._collect(self.submit_search(sspec), SearchJobResult)

    def _collect(self, stream, result_cls):
        events = []
        job_id, total, skipped = "", 0, []
        outcome = "disconnected"
        for ev in stream:
            events.append(ev)
            if ev["type"] == "job":
                job_id, total = ev["job_id"], ev["total"]
                skipped = ev.get("skipped", [])
            elif ev["type"] in TERMINAL_EVENTS:
                outcome = ev["type"]
                if ev["type"] == "done":
                    total = ev.get("total", total)  # searches grow total
        if not job_id:
            raise ProtocolError("stream ended before the job header")
        return result_cls(job_id, total, skipped, events, outcome)
