"""Chaos bench: the sweep service under a seeded kill/hang/corrupt schedule.

Three phases against real ``python -m repro.serve`` processes:

1. **Baseline** — a fault-free campaign on a fresh cache records the
   reference rows (and, in ``--tiny`` mode, checks their trace
   fingerprints against ``benchmarks/golden_hashes_tiny.json``).
2. **Chaos** — the same campaign on a fresh cache, plus an overlapping
   second client, under a deterministic
   :class:`repro.distributed.faults.FaultPlan`: one worker **crash**, one
   worker **hang** past the liveness deadline, one chunk returning
   **corrupt records**, one **poison scenario** that kills every worker
   touching it, and one pre-seeded **corrupt cache record** on disk.  The
   campaign must converge: the poison scenario surfaces as a structured
   quarantined error row; every other row must be *byte-identical* to the
   baseline; the corrupted cache record must be quarantined to ``*.bad``
   and silently re-executed.
3. **Restart** — a campaign is SIGKILLed mid-flight (no drain, no
   goodbye); a restarted server must resume the job from the crash-safe
   journal, re-executing only the uncached tail, and the final rows must
   again match the baseline byte for byte.

Measured: chaos wall-clock overhead vs baseline, worker losses/respawns,
re-dispatches, poison quarantines, corrupt-record catches, and the
recovery split (cached vs re-executed) after the SIGKILL.

    PYTHONPATH=src python -m benchmarks.bench_faults          # full
    PYTHONPATH=src python -m benchmarks.bench_faults --tiny   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time

from repro.distributed.faults import FaultPlan, FaultRule, plan_to_json
from repro.graph.generators import GraphSpec
from repro.serve.client import ServeClient
from repro.serve.journal import JobJournal
from repro.sweep.cache import ResultCache, scenario_hash
from repro.sweep.spec import SweepSpec

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_hashes_tiny.json")

TINY_SPEC = SweepSpec(
    name="faults-tiny",
    accelerators=("accugraph", "foregraph", "hitgraph", "thundergp"),
    graphs=(GraphSpec("tiny", "uniform", 256, 1024, True, 1, 0),),
    problems=("bfs",),
    drams=("default", "hbm"),
)

FULL_SPEC = SweepSpec(
    name="faults-full",
    accelerators=("accugraph", "foregraph", "hitgraph", "thundergp"),
    graphs=("sd",),
    problems=("bfs", "pr"),
    drams=("default", "hbm"),
)


def canonical(row: dict) -> str:
    return json.dumps(row, sort_keys=True, separators=(",", ":"))


def start_server(tmp: str, *extra, workers: int = 2, chunk_size: int = 1,
                 trace_hashes: bool = False):
    port_file = os.path.join(tmp, "port")
    if os.path.exists(port_file):
        os.remove(port_file)  # a SIGKILLed predecessor leaves it behind
    cmd = [sys.executable, "-m", "repro.serve", "--port", "0",
           "--port-file", port_file, "--cache", os.path.join(tmp, "c"),
           "--workers", str(workers), "--chunk-size", str(chunk_size),
           "--quiet", *extra]
    if trace_hashes:
        cmd.append("--trace-hashes")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(cmd, env=env)
    deadline = time.time() + 180
    while not os.path.exists(port_file) or not open(port_file).read().strip():
        if proc.poll() is not None:
            raise RuntimeError(f"server exited early: rc={proc.returncode}")
        if time.time() > deadline:
            proc.kill()
            raise RuntimeError("server never wrote its port file")
        time.sleep(0.1)
    address = open(port_file).read().strip()
    client = ServeClient(address)
    client.wait_ready(deadline_s=60)
    return proc, client


def stop_server(proc, client) -> int:
    client.shutdown()
    return proc.wait(timeout=120)


# ---- phase 1: fault-free baseline -------------------------------------------


def run_baseline(spec: SweepSpec, tiny: bool) -> dict:
    tmp = tempfile.mkdtemp(prefix="bench_faults_base_")
    proc, client = start_server(tmp, chunk_size=2, trace_hashes=tiny)
    scenarios, _ = spec.expand()
    print(f"[bench_faults] baseline: {len(scenarios)} scenarios, no faults")
    t0 = time.time()
    res = client.run(spec)
    wall = time.time() - t0
    assert res.outcome == "done", f"baseline ended {res.outcome!r}"
    assert res.statuses == ["ok"] * len(scenarios), res.statuses

    golden_checked = 0
    if tiny:
        golden = json.load(open(GOLDEN))
        served = {scenarios[ev["index"]].scenario_id: ev["trace_hash"]
                  for ev in res.row_events}
        mismatches = {sid: (h, golden.get(sid)) for sid, h in served.items()
                      if golden.get(sid) != h}
        assert not mismatches, f"trace hashes diverged: {mismatches}"
        golden_checked = len(served)
        print(f"  golden: {golden_checked}/{len(golden)} trace hashes match")

    rc = stop_server(proc, client)
    assert rc == 0, f"baseline drain exited {rc}"
    print(f"  {len(res.rows)} rows in {wall:.1f}s")
    return dict(rows=res.rows, wall_s=round(wall, 3),
                golden_checked=golden_checked)


# ---- phase 2: seeded chaos --------------------------------------------------


def chaos_plan(poison_id: str) -> FaultPlan:
    """Deterministic schedule keyed to the scheduler's dispatch counter.
    With chunk size 1 and a FIFO queue, dispatch *i* of the first round is
    expansion-scenario *i*, so the crash/hang/corrupt indices each hit a
    distinct innocent scenario while the match rule rides the poison
    scenario through every one of its (re-)dispatches."""
    return FaultPlan(seed=20260808, rules=(
        FaultRule("worker.chunk", "crash", match=poison_id),  # the poison
        FaultRule("worker.chunk", "crash", at=(1,)),
        FaultRule("worker.chunk", "hang", at=(3,)),
        FaultRule("worker.chunk", "corrupt", at=(5,)),
    ))


def run_chaos(spec: SweepSpec, baseline_rows: list[dict]) -> dict:
    tmp = tempfile.mkdtemp(prefix="bench_faults_chaos_")
    scenarios, _ = spec.expand()
    poison_idx = len(scenarios) - 1
    poison_id = scenarios[poison_idx].scenario_id
    plan = chaos_plan(poison_id)

    # pre-seed a corrupted cache record at scenario 0's content address:
    # the server must quarantine it as a miss, never serve it
    cache = ResultCache(os.path.join(tmp, "c"))
    bad_path = cache.path(scenario_hash(scenarios[0]))
    os.makedirs(os.path.dirname(bad_path), exist_ok=True)
    with open(bad_path, "w") as f:
        f.write('{"sha256": "torn mid-wri')

    overlap = SweepSpec(name=spec.name + "-overlap",
                        accelerators=spec.accelerators[:2],
                        graphs=spec.graphs, problems=spec.problems,
                        drams=spec.drams)
    n_overlap = len(overlap.expand()[0])

    proc, client = start_server(
        tmp, "--worker-deadline", "3", "--poison-threshold", "2",
        "--faults", plan_to_json(plan))
    print(f"[bench_faults] chaos: {len(scenarios)} scenarios + "
          f"{n_overlap} overlapping, poison={poison_id}")

    results: dict[str, object] = {}
    t0 = time.time()

    def run_client(name, s):
        results[name] = ServeClient(f"{client.host}:{client.port}").run(s)

    main_t = threading.Thread(target=run_client, args=("main", spec))
    main_t.start()
    time.sleep(0.5)  # main job queues first: dispatch i == scenario i
    over_t = threading.Thread(target=run_client, args=("overlap", overlap))
    over_t.start()
    main_t.join(timeout=1800)
    over_t.join(timeout=1800)
    wall = time.time() - t0
    res, over = results["main"], results["overlap"]

    assert res.outcome == "done", f"chaos campaign ended {res.outcome!r}"
    assert over.outcome == "done", f"overlap job ended {over.outcome!r}"

    # exactly one poison quarantine, surfaced as a structured error row
    assert res.n_poisoned == 1, f"poisoned rows: {res.n_poisoned}"
    prow = res.rows[poison_idx]
    assert prow.get("poison") is True and "quarantined" in prow["error"], prow
    assert prow["attempts"] == 2, prow

    # every other row converged byte-identically to the fault-free run
    diverged = [i for i in range(len(scenarios)) if i != poison_idx
                and canonical(res.rows[i]) != canonical(baseline_rows[i])]
    assert not diverged, f"rows diverged from baseline: {diverged}"
    assert over.n_errors == 0
    over_diverged = [i for i, row in enumerate(over.rows)
                     if canonical(row) != canonical(baseline_rows[i])]
    assert not over_diverged, f"overlap rows diverged: {over_diverged}"

    # the pre-corrupted cache record was quarantined aside and re-executed
    assert os.path.exists(bad_path + ".bad"), "corrupt record not quarantined"
    assert not os.path.exists(bad_path) or cache.get(
        scenario_hash(scenarios[0])) is not None

    stats = client.stats()
    faults = stats["faults"]
    assert faults["chunks_lost"] >= 3, faults      # >=1 crash, 1 hang, poison
    assert faults["scenarios_poisoned"] == 1, faults
    assert faults["corrupt_records"] >= 1, faults  # the mangled chunk
    assert faults["workers_lost"] >= 3, faults
    rc = stop_server(proc, client)
    assert rc == 0, f"chaos drain exited {rc}"
    print(f"  converged in {wall:.1f}s: {len(scenarios) - 1} rows identical, "
          f"1 poison row; faults={faults}")
    return dict(wall_s=round(wall, 3), poison_scenario=poison_id,
                rows_identical=len(scenarios) - 1, overlap_rows=n_overlap,
                faults=faults,
                inflight_joins=stats["counters"].get("inflight_joins", 0))


# ---- phase 3: SIGKILL + journal restart -------------------------------------


def run_restart(spec: SweepSpec, baseline_rows: list[dict]) -> dict:
    tmp = tempfile.mkdtemp(prefix="bench_faults_restart_")
    scenarios, _ = spec.expand()
    proc, client = start_server(tmp, workers=1)
    print(f"[bench_faults] restart: SIGKILL mid-campaign, then resume")

    state = dict(job_id="", rows=0)
    killed = threading.Event()

    def stream():
        try:
            for ev in client.submit(spec):
                if ev["type"] == "job":
                    state["job_id"] = ev["job_id"]
                elif ev["type"] == "row":
                    state["rows"] += 1
                    if state["rows"] >= 2 and not killed.is_set():
                        os.kill(proc.pid, signal.SIGKILL)  # no drain, no ack
                        killed.set()
        except OSError:
            pass  # the connection dies with the server

    t = threading.Thread(target=stream)
    t.start()
    t.join(timeout=600)
    assert killed.is_set(), "never reached 2 rows to kill at"
    proc.wait(timeout=60)
    jid, rows_before = state["job_id"], state["rows"]

    # the journal survived the SIGKILL with the job still open
    open_ids = [op["id"] for op in JobJournal(os.path.join(tmp, "c")
                                              ).load_open()]
    assert jid in open_ids, f"journal lost job {jid}: {open_ids}"

    proc2, client2 = start_server(tmp, workers=1)
    t0 = time.time()
    deadline = time.time() + 900
    while True:
        status = client2.job_status(jid)
        if status.get("finished"):
            break
        if time.time() > deadline:
            raise RuntimeError(f"recovered job never finished: {status}")
        time.sleep(0.25)
    recover_wall = time.time() - t0
    assert status["recovered"], status
    counts = status["counts"]
    # resumed from the journal + cache: only the unfinished tail re-executed
    assert counts.get("cached", 0) >= rows_before, (counts, rows_before)
    assert counts.get("cached", 0) + counts.get("ok", 0) == len(scenarios)
    assert counts.get("ok", 0) >= 1, counts

    # and the converged state is byte-identical to the fault-free run
    res = client2.run(spec)
    assert res.outcome == "done"
    assert res.statuses == ["cached"] * len(scenarios), res.statuses
    diverged = [i for i, row in enumerate(res.rows)
                if canonical(row) != canonical(baseline_rows[i])]
    assert not diverged, f"post-recovery rows diverged: {diverged}"
    rc = stop_server(proc2, client2)
    assert rc == 0, f"restarted server drain exited {rc}"
    print(f"  recovered {counts.get('cached', 0)} cached + "
          f"{counts.get('ok', 0)} re-executed in {recover_wall:.1f}s")
    return dict(rows_before_kill=rows_before,
                recovered_cached=counts.get("cached", 0),
                recovered_executed=counts.get("ok", 0),
                recover_wall_s=round(recover_wall, 3))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny grid + golden trace hashes")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args(argv)

    spec = TINY_SPEC if args.tiny else FULL_SPEC
    baseline = run_baseline(spec, tiny=args.tiny)
    chaos = run_chaos(spec, baseline["rows"])
    restart = run_restart(spec, baseline["rows"])

    result = dict(
        mode="tiny" if args.tiny else "full",
        scenarios=len(spec.expand()[0]),
        baseline=dict(wall_s=baseline["wall_s"],
                      golden_checked=baseline["golden_checked"]),
        chaos=chaos,
        restart=restart,
        chaos_overhead=round(chaos["wall_s"] / max(1e-9, baseline["wall_s"]),
                             3),
    )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[bench_faults] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
