"""Loop-aware analysis of post-partitioning HLO text.

XLA's ``compiled.cost_analysis()`` visits every instruction ONCE — a
``jax.lax.scan`` over 28 layers contributes the flops of a single layer.
Since the whole framework leans on scanned layer stacks (and sequence scans
for SSMs / blocked attention), that undercounts by the trip count.  This
module re-derives the roofline inputs from ``compiled.as_text()`` with loop
multiplicity:

- the module is parsed into computations and a callgraph
  (while/call/conditional/fusion edges),
- while trip counts are recovered from the scan-style condition
  (``compare(gte(param), constant(N)), direction=LT``),
- FLOPs: 2 * prod(result dims) * prod(contracting dims) per ``dot``
  (+ an analogous estimate per ``convolution``) — the MXU term;
  elementwise vector-unit flops are deliberately excluded,
- bytes: operand + result bytes of every *sequenced* instruction
  (fusions count at their boundary — operands and outputs, i.e. the
  HBM-traffic proxy; parameter/constant/tuple plumbing is excluded),
- collective bytes: result-shape bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, times loop multiplicity.

Validated against hand-countable programs in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops that are pure data plumbing at the sequenced level
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "iota",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z0-9-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\{\s*$")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str  # operand list + attributes

    def operand_names(self) -> list[str]:
        # operands are inside the first balanced paren group of `rest`
        depth, end = 1, len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        inside = self.rest[:end]
        return re.findall(r"%[\w\.\-]+", inside)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=(%?[\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str) -> list[int]:
        m = re.search(rf"{key}=\{{([0-9, ]*)\}}", self.rest)
        if not m or not m.group(1).strip():
            return []
        return [int(x) for x in m.group(1).split(",")]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]

    def symbols(self) -> dict[str, str]:
        return {i.name: i.result_type for i in self.instrs}


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and "{" in line:
                name = m.group(1).lstrip("%")
                cur = Computation(name, [])
                if line.strip().startswith("ENTRY"):
                    entry_name = name
            continue
        s = line.strip()
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            nm, tp, op, rest = mi.groups()
            cur.instrs.append(Instr(nm.lstrip("%"), tp, op, rest))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation) -> int:
    """Recover the scan trip count from the condition computation."""
    const = None
    direction = None
    for i in cond.instrs:
        if i.op == "constant" and i.result_type.startswith(("s32[]", "s64[]", "u32[]")):
            m = re.search(r"constant\((-?\d+)\)", i.op + "(" + i.rest)
            if m:
                const = int(m.group(1))
        if i.op == "compare":
            m = re.search(r"direction=(\w+)", i.rest)
            direction = m.group(1) if m else None
    if const is None:
        return 1
    if direction in ("LT", "GT", None):
        return max(const, 1)
    if direction in ("LE", "GE"):
        return max(const + 1, 1)
    return max(const, 1)


def _dot_flops(instr: Instr, symbols: dict[str, str]) -> float:
    out_dims = []
    for _, dims in _shape_dims(instr.result_type):
        out_dims = dims
        break
    out_n = 1
    for d in out_dims:
        out_n *= d
    ops = instr.operand_names()
    lhs_type = symbols.get(ops[0].lstrip("%"), "") if ops else ""
    lhs_dims = _shape_dims(lhs_type)
    lhs = lhs_dims[0][1] if lhs_dims else []
    contract = instr.attr_list("lhs_contracting_dims")
    k = 1
    for c in contract:
        if c < len(lhs):
            k *= lhs[c]
    return 2.0 * out_n * max(k, 1)


def _conv_flops(instr: Instr, symbols: dict[str, str]) -> float:
    out_n = 1
    for _, dims in _shape_dims(instr.result_type):
        for d in dims:
            out_n *= d
        break
    ops = instr.operand_names()
    rhs_type = symbols.get(ops[1].lstrip("%"), "") if len(ops) > 1 else ""
    rhs_dims = _shape_dims(rhs_type)
    rhs_n = 1
    for d in (rhs_dims[0][1] if rhs_dims else []):
        rhs_n *= d
    # per output element: one MAC per kernel element per input-channel slice;
    # approximate with prod(rhs)/out_features (exact for depthwise/dense 2d)
    out_feat = (rhs_dims[0][1][-1] if rhs_dims and rhs_dims[0][1] else 1) or 1
    m = re.search(r"feature_group_count=(\d+)", instr.rest)
    groups = int(m.group(1)) if m else 1
    return 2.0 * out_n * max(rhs_n // max(out_feat, 1), 1) / max(groups, 1) * groups / groups


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    bytes: float = 0.0  # op-level operands+results (upper bound)
    result_bytes: float = 0.0  # sequenced results only (traffic proxy input)
    param_bytes: float = 0.0  # parameters read (entry-level)
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)

    def add(self, other: "Stats", mult: float = 1.0, with_bytes: bool = True):
        self.flops += other.flops * mult
        if with_bytes:
            self.bytes += other.bytes * mult
            self.result_bytes += other.result_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v * mult
        self.loops.extend(other.loops)


def _root_instr(comp: Computation) -> Optional[Instr]:
    return comp.instrs[-1] if comp.instrs else None


def _result_traffic(i: Instr, symbols: dict[str, str], comps: dict) -> float:
    """Result bytes for the traffic proxy.  In-place buffer updates
    (dynamic-update-slice / scatter, bare or as a fusion root) count the
    update, not the whole aliased buffer."""
    if i.op in ("dynamic-update-slice", "scatter"):
        ops = i.operand_names()
        if len(ops) > 1:
            return shape_bytes(symbols.get(ops[1].lstrip("%"), ""))
    if i.op == "fusion":
        callee = i.attr("calls")
        comp = comps.get(callee.lstrip("%")) if callee else None
        root = _root_instr(comp) if comp else None
        if root is not None and root.op == "dynamic-update-slice":
            rops = root.operand_names()
            csym = comp.symbols()
            if len(rops) > 1:
                return shape_bytes(csym.get(rops[1].lstrip("%"), ""))
    return shape_bytes(i.result_type)


def _analyze_comp(
    comps: dict[str, Computation], name: str, memo: dict, depth: int = 0
) -> Stats:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    st = Stats()
    if comp is None or depth > 64:
        memo[name] = st
        return st
    symbols = comp.symbols()
    for i in comp.instrs:
        # flops
        if i.op == "dot":
            st.flops += _dot_flops(i, symbols)
        elif i.op == "convolution":
            st.flops += _conv_flops(i, symbols)
        # collectives
        base = None
        for c in COLLECTIVE_OPS:
            if i.op == c or i.op == c + "-start":
                base = c
                break
        if base is not None:
            b = shape_bytes(i.result_type)
            st.coll_bytes += b
            st.coll_by_op[base] = st.coll_by_op.get(base, 0.0) + b
        # bytes (sequenced-instruction traffic); parameters are handled at
        # the entry level only (loop-body parameters are carried state)
        if i.op not in _NO_BYTES and not i.op.endswith("-done"):
            rb = _result_traffic(i, symbols, comps)
            b = shape_bytes(i.result_type)
            for opn in i.operand_names():
                b += shape_bytes(symbols.get(opn.lstrip("%"), ""))
            st.bytes += b
            st.result_bytes += rb
        # recursion
        if i.op == "while":
            body = i.attr("body")
            cond = i.attr("condition")
            # primary: XLA's own analysis on the instruction
            m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', i.rest)
            if m:
                trip = int(m.group(1))
            elif cond and cond.lstrip("%") in comps:
                trip = _trip_count(comps[cond.lstrip("%")])
            else:
                trip = 1
            if body:
                sub = _analyze_comp(comps, body.lstrip("%"), memo, depth + 1)
                st.add(sub, mult=trip)
                st.loops.append({"body": body.lstrip("%"), "trip": trip})
        elif i.op == "fusion":
            callee = i.attr("calls")
            if callee:
                sub = _analyze_comp(comps, callee.lstrip("%"), memo, depth + 1)
                # flops inside the fusion count; bytes counted at the boundary
                st.add(sub, mult=1.0, with_bytes=False)
        elif i.op == "call":
            callee = i.attr("to_apply")
            if callee:
                st.add(_analyze_comp(comps, callee.lstrip("%"), memo, depth + 1))
        elif i.op == "conditional":
            for m in re.finditer(r"%[\w\.\-]+_computation[\w\.\-]*", i.rest):
                cn = m.group(0).lstrip("%")
                if cn in comps:
                    st.add(_analyze_comp(comps, cn, memo, depth + 1))
    memo[name] = st
    return st


def top_ops(text: str, k: int = 20, by: str = "traffic") -> list[dict]:
    """Largest contributors with loop multiplicity — the §Perf profile.

    by: "traffic" (result bytes), "collective", or "flops"."""
    comps = parse_module(text)
    mult: dict[str, float] = {"__entry__": 1.0}
    # propagate multipliers breadth-first through while edges
    entry = comps.get("__entry__")
    frontier = [("__entry__", 1.0)]
    seen = set()
    while frontier:
        name, m = frontier.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for i in comps[name].instrs:
            if i.op == "while":
                body = i.attr("body")
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', i.rest)
                trip = int(mt.group(1)) if mt else 1
                if body:
                    mult[body.lstrip("%")] = m * trip
                    frontier.append((body.lstrip("%"), m * trip))
            elif i.op in ("call",):
                callee = i.attr("to_apply")
                if callee:
                    mult[callee.lstrip("%")] = m
                    frontier.append((callee.lstrip("%"), m))
    rows = []
    for name, m in mult.items():
        comp = comps.get(name)
        if comp is None:
            continue
        symbols = comp.symbols()
        for i in comp.instrs:
            if i.op in _NO_BYTES or i.op.endswith("-done"):
                continue
            if by == "collective":
                if not any(i.op.startswith(c) for c in COLLECTIVE_OPS):
                    continue
                val = shape_bytes(i.result_type) * m
            elif by == "flops":
                if i.op == "dot":
                    val = _dot_flops(i, symbols) * m
                elif i.op == "convolution":
                    val = _conv_flops(i, symbols) * m
                else:
                    continue
            else:
                val = _result_traffic(i, symbols, comps) * m
            if val > 0:
                rows.append({"value": val, "op": i.op, "type": i.result_type[:80],
                             "comp": name, "mult": m,
                             "meta": i.rest[-120:] if "metadata" in i.rest else ""})
    rows.sort(key=lambda r: -r["value"])
    return rows[:k]


def analyze_hlo(text: str) -> dict:
    """Loop-aware per-device totals from post-partitioning HLO text.

    Returns two byte measures:
    - ``bytes_op_level``: operands+results of every sequenced instruction
      (HloCostAnalysis convention; counts every def-use edge — upper bound),
    - ``bytes``: the HBM-traffic proxy used for the roofline memory term:
      entry parameters read once + each produced value written once and
      read once (2 x result bytes).
    """
    comps = parse_module(text)
    memo: dict[str, Stats] = {}
    st = _analyze_comp(comps, "__entry__", memo)
    entry_params = 0
    if "__entry__" in comps:
        for i in comps["__entry__"].instrs:
            if i.op == "parameter":
                entry_params += shape_bytes(i.result_type)
    traffic = entry_params + 2.0 * st.result_bytes
    return {
        "flops": st.flops,
        "bytes": traffic,
        "bytes_op_level": st.bytes,
        "entry_param_bytes": entry_params,
        "collective_bytes": st.coll_bytes,
        "collectives_by_op": {k: float(v) for k, v in st.coll_by_op.items()},
        "n_loops": len(st.loops),
        "loops": st.loops[:32],
    }
