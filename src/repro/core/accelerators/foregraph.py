"""ForeGraph model (Dai et al., FPGA'17) — paper Sect. 3.2.2, Fig. 5.

Edge-centric on interval-shard (GridGraph-style) partitioning with a
compressed edge list (two 16-bit local vertex ids per edge -> 4 bytes/edge;
possible because intervals are limited to 65,536 vertices), immediate update
propagation, p processing elements sharing memory round-robin.

Per iteration: for each source interval i (PE i % p): prefetch interval i's
values sequentially; for each shard (i, j): prefetch destination interval j,
read the shard's edges sequentially, then write the destination interval
back sequentially.  All off-chip requests are sequential; random vertex
value accesses are served on-chip.

Optimizations (paper Sect. 4.5):
- shard skipping:  skip shards whose source interval did not change,
- stride mapping:  rename vertices with a constant stride to balance
  interval degrees,
- edge shuffling:  zip the edge lists of p consecutive destination shards
  into one (padding with null edges) so p PEs stream one merged list —
  alone this *hurts* (padding => more edges read, aggravated by partition
  skew), combined with stride mapping the padding shrinks.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np

from repro.core import semexec
from repro.core.accelerators.base import (
    Accelerator,
    INF,
    PhasedTrace,
)
from repro.core.hostcache import ARTIFACTS
from repro.core.memory_layout import MemoryLayout
from repro.core.metrics import IterationStats
from repro.core.trace import (
    Trace,
    concat,
    proportional_interleave,
    seq_read,
    seq_write,
)
from repro.graph.layout import partition_balance, relabel_values, undo_relabel
from repro.graph.partition import interval_shard_partition, stride_mapping
from repro.graph.problems import Problem
from repro.graph.structure import Graph

INTERVAL_CAP = 65536  # 16-bit local vertex ids in the compressed edge format

# effective-interval clamps already warned about (one warning per distinct
# (interval_size, interval_scale) pair, not one per execution)
_CLAMP_WARNED: set[tuple[int, int]] = set()


class ForeGraph(Accelerator):
    name = "foregraph"
    default_dram = "foregraph"
    supports_weights = False
    supports_multichannel = False

    def __init__(self, config=None):
        super().__init__(config)
        if self.config.effective_interval > INTERVAL_CAP:
            raise ValueError(
                f"ForeGraph intervals are limited to 65,536 vertices; "
                f"interval_size={self.config.interval_size} x "
                f"interval_scale={self.config.interval_scale} = "
                f"{self.config.effective_interval}")

    def _execute(self, g: Graph, problem: Problem, root: int,
                 init=None, engine="numpy"):
        cfg = self.config
        n_pes = max(cfg.n_pes, 1)
        interval = cfg.effective_interval
        if interval > INTERVAL_CAP:
            # __init__ rejects this; a config swapped in after construction
            # can still reach it — clamp loudly (once per config) instead of
            # silently, and report the interval actually used
            key = (cfg.interval_size, cfg.interval_scale)
            if key not in _CLAMP_WARNED:
                _CLAMP_WARNED.add(key)
                warnings.warn(
                    f"ForeGraph effective interval {interval} exceeds the "
                    f"{INTERVAL_CAP} 16-bit local-id cap; clamping to "
                    f"{INTERVAL_CAP}", UserWarning, stacklevel=2)
            interval = INTERVAL_CAP

        sperm = None
        if cfg.has("stride_mapping"):
            q_est = max(1, -(-g.n // interval))
            sperm = stride_mapping(g.n, q_est)
            g = g.renamed(sperm)
            root = int(sperm[root])

        shards = interval_shard_partition(g, interval)
        q = shards.q
        layout = MemoryLayout()
        layout.alloc("values", g.n * 4)
        # Static shard state, hoisted out of the iteration loop: sizes and
        # the gathered per-shard endpoint arrays (only non-empty shards).
        sizes, shard_edges = ARTIFACTS.get_or_build(
            (g.fingerprint, "foregraph.prep", interval),
            lambda: (
                shards.shard_sizes(),
                {
                    (i, j): shards.shard(i, j)
                    for i in range(q)
                    for j in range(q)
                    if len(shards.shard_edge_idx[i][j])
                },
            ),
        )
        # balance over the q x q shard grid (shards ARE ForeGraph's
        # partitions); shard_fill = fraction of non-empty shards — the
        # id-locality effect behind the paper's ForeGraph numbers
        extras = dict(
            effective_interval=interval,
            balance=partition_balance(sizes.ravel(), total_slots=q * q),
        )
        for i in range(q):
            for j in range(q):
                if sizes[i, j]:
                    layout.alloc(f"sh{i}_{j}", int(sizes[i, j]) * 4)  # 4B compressed edges

        if init is None:
            values = problem.init_values(g, root)
        else:
            # the passed init is in pre-stride id space: carry each
            # vertex's payload through the stride renaming as well
            values = relabel_values(init, sperm) if sperm is not None else init.copy()
        src_deg = g.degrees_out.astype(np.float32) if problem.name == "pr" else None

        shuffle = cfg.has("edge_shuffling") and n_pes > 1
        skip = cfg.has("shard_skipping") and problem.kind == "min"
        dirty = np.ones(q, dtype=bool)
        device = engine == "device"
        if device:
            dev = semexec.ForeGraphDevice(g, problem, sizes, shard_edges,
                                          interval, q)
            values_dev = jnp.asarray(values)
        pt = PhasedTrace()
        stats: list[IterationStats] = []
        iters = 0

        base_const = (1.0 - 0.85) / g.n if problem.name == "pr" else 0.0

        for _ in range(cfg.max_iters):
            iters += 1
            st = IterationStats(partitions_total=q * q)
            any_change = False
            pe_traces: list[list[Trace]] = [[] for _ in range(n_pes)]
            if problem.kind == "acc":
                if device:
                    # every shard reads the pre-iteration snapshot: the
                    # whole accumulation fuses into one device dispatch
                    values_dev = dev.acc_step(values_dev)
                else:
                    snapshot = values.copy()
                    values = np.full(g.n, base_const, dtype=np.float32)

            for i in range(q):
                if skip and not dirty[i]:
                    st.partitions_skipped += q
                    continue
                dirty[i] = False
                if device and problem.kind == "min":
                    # one fused dispatch per source interval (three
                    # sequential sub-scatters reproduce the shard-order
                    # Gauss-Seidel); later intervals' skip decisions need
                    # this interval's dirty flags, hence the host sync here
                    values_dev, flags = dev.min_step(values_dev, i)
                    if flags.any():
                        any_change = True
                        dirty |= flags
                pe = i % n_pes
                lo_i, hi_i = shards.interval(i)
                pe_traces[pe].append(
                    seq_read(layout.base("values") + lo_i * 4, (hi_i - lo_i) * 4)
                )
                st.values_read += hi_i - lo_i

                # group destination shards for edge shuffling
                j_groups = (
                    [list(range(jj, min(jj + n_pes, q))) for jj in range(0, q, n_pes)]
                    if shuffle
                    else [[j] for j in range(q)]
                )
                for group in j_groups:
                    group = [j for j in group if sizes[i, j] > 0]
                    if not group:
                        continue
                    pad = max(int(sizes[i, j]) for j in group) if shuffle else 0
                    for j in group:
                        lo_j, hi_j = shards.interval(j)
                        if not device:
                            src, dst = shard_edges[(i, j)]
                            # --- semantics (immediate across shards; the
                            # shard only updates destination interval j, so
                            # the accumulation scratch is interval-local) ---
                            sv = (snapshot if problem.kind == "acc" else values)[src]
                            if problem.kind == "min":
                                cand = problem.edge_candidates_np(sv)
                                acc = np.full(hi_j - lo_j, INF, dtype=np.float32)
                                np.minimum.at(acc, dst - lo_j, cand)
                                old = values[lo_j:hi_j]
                                nv = np.minimum(old, acc)
                                changed = (nv < old).nonzero()[0] + lo_j
                                values[lo_j:hi_j] = nv
                                if len(changed):
                                    any_change = True
                                    dirty[np.unique(changed // interval)] = True
                            else:
                                cand = problem.edge_candidates_np(
                                    sv, None,
                                    src_deg[src] if src_deg is not None else None,
                                )
                                acc = np.zeros(hi_j - lo_j, dtype=np.float32)
                                np.add.at(acc, dst - lo_j, cand)
                                scale = 0.85 if problem.name == "pr" else 1.0
                                values[lo_j:hi_j] += np.float32(scale) * acc

                        # --- trace (all sequential) ---
                        n_edges = pad if shuffle else int(sizes[i, j])
                        tr = concat(
                            seq_read(layout.base("values") + lo_j * 4, (hi_j - lo_j) * 4),
                            seq_read(layout.base(f"sh{i}_{j}"), n_edges * 4),
                            seq_write(layout.base("values") + lo_j * 4, (hi_j - lo_j) * 4),
                        )
                        st.values_read += hi_j - lo_j
                        st.values_written += hi_j - lo_j
                        st.edges_read += n_edges
                        pe_traces[pe].append(tr)

            # PEs share the single memory channel round-robin (Sect. 3.2.2);
            # concurrently-streaming PEs -> proportional interleave.
            pe_cat = [concat(*trs) for trs in pe_traces if trs]
            if pe_cat:
                merged = pe_cat[0] if len(pe_cat) == 1 else proportional_interleave(*pe_cat)
                pt.add_phase([merged])
            stats.append(st)
            if problem.single_iteration:
                break
            if problem.kind == "min" and (not any_change or (skip and not dirty.any())):
                break

        if device:
            values = np.asarray(values_dev)
        if sperm is not None:
            # values are indexed by stride-renamed ids; map back to the
            # pre-stride ids (WCC labels re-canonicalised to min id)
            values = undo_relabel(values, sperm, problem.name)
        return values, iters, pt, stats, extras
