"""Public op: one min-propagation relaxation step over a Graph."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph
from repro.kernels.edge_update.edge_update import edge_update_pallas
from repro.kernels.edge_update.ref import edge_update_ref


def relax_step(
    g: Graph,
    values: np.ndarray,
    problem: str = "bfs",
    *,
    use_pallas: bool | None = None,
    block: int = 1024,
    interpret: bool | None = None,
) -> np.ndarray:
    """new_values = min(values, segment_min_dst(values[src] + delta))."""
    if problem == "bfs":
        delta = np.ones(g.m, dtype=np.float32)
    elif problem == "wcc":
        delta = np.zeros(g.m, dtype=np.float32)
    elif problem == "sssp":
        assert g.weights is not None
        delta = g.weights
    else:
        raise ValueError(problem)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    v = jnp.asarray(values, dtype=jnp.float32)
    if use_pallas or interpret:
        pad = (-g.m) % block
        src = np.concatenate([g.src, np.full(pad, -1, dtype=np.int32)])
        dst = np.concatenate([g.dst, np.zeros(pad, dtype=np.int32)])
        dl = np.concatenate([delta, np.zeros(pad, dtype=np.float32)])
        on_tpu = jax.default_backend() == "tpu"
        acc = edge_update_pallas(
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(dl), v,
            block=block, interpret=(not on_tpu) if interpret is None else interpret,
        )
    else:
        acc = edge_update_ref(jnp.asarray(g.src), jnp.asarray(g.dst),
                              jnp.asarray(delta), v, g.n)
    return np.asarray(jnp.minimum(v, acc))
