import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Dry-run profiler: re-lowers one (arch x shape x mesh) cell and prints the
# top traffic / collective / flops contributors with loop multiplicity —
# the "profile" of the hypothesis->change->measure loop (§Perf).
#
#   PYTHONPATH=src python -m repro.roofline.profile --arch qwen3_0_6b \
#       --shape decode_32k [--mesh single] [--by traffic|collective|flops]

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--by", default="traffic",
                    choices=["traffic", "collective", "flops"])
    ap.add_argument("--top", type=int, default=18)
    args = ap.parse_args()

    from repro.configs.base import SHAPES, get_arch
    from repro.launch.dryrun import input_specs, optimizer_config_for
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model
    from repro.roofline.hlo import analyze_hlo, top_ops
    from repro.train import optimizer as opt
    from repro.train.train_step import TrainConfig, jit_train_step

    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    model = Model(cfg)
    params_abs = model.init_abstract()
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = TrainConfig(optimizer=optimizer_config_for(cfg))
        opt_abs = jax.eval_shape(lambda p: opt.init(tcfg.optimizer, p), params_abs)
        lowered = jit_train_step(model, mesh, tcfg)(specs).lower(
            params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        from repro.serve.legacy.serve_step import jit_serve_steps

        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        prefill, _, _ = jit_serve_steps(model, mesh, shape.global_batch,
                                        shape.seq_len, batch_abstract=specs)
        lowered = prefill.lower(params_abs, specs, cache_abs)
    else:
        from repro.serve.legacy.serve_step import jit_serve_steps

        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        _, decode, _ = jit_serve_steps(model, mesh, shape.global_batch,
                                       shape.seq_len)
        lowered = decode.lower(params_abs, specs["tokens"], cache_abs,
                               jax.ShapeDtypeStruct((), "int32"))

    hlo = lowered.compile().as_text()
    a = analyze_hlo(hlo)
    print(f"flops/dev {a['flops']:.3e}  traffic/dev {a['bytes']/2**30:.2f} GiB  "
          f"coll/dev {a['collective_bytes']/2**30:.2f} GiB  loops {a['n_loops']}")
    print(f"collectives by op: "
          f"{ {k: round(v/2**30,2) for k,v in a['collectives_by_op'].items()} } GiB")
    unit = "GiB" if args.by != "flops" else "GFLOP"
    div = 2**30 if args.by != "flops" else 1e9
    print(f"\ntop {args.top} by {args.by}:")
    for r in top_ops(hlo, k=args.top, by=args.by):
        meta = ""
        if r["meta"]:
            import re as _re
            m = _re.search(r'op_name="([^"]+)"', r["meta"])
            meta = m.group(1)[-60:] if m else ""
        print(f"  {r['value']/div:9.2f} {unit}  x{int(r['mult']):>5d} "
              f"{r['op']:24s} {r['type'][:48]:48s} {meta}")


if __name__ == "__main__":
    main()
