"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent
per-channel decay, matrix-valued state (head dim 64)."""
from repro.configs.base import ArchConfig, register

RWKV6_1_6B = register(ArchConfig(
    arch="rwkv6_1_6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # 2048 / 64 rwkv heads (informational; attention-free)
    n_kv_heads=32,
    d_ff=7168,
    vocab=65_536,
    rwkv_head_dim=64,
    notes="attention-free: O(1) state per token; runs long_500k",
))
