"""DRAM timing engines.

Two engines with identical request-level semantics:

1. ``simulate_channel_scan`` — the exact sequential model (``jax.lax.scan``
   over requests, carrying per-bank state).  This is the correctness oracle
   (``kernels/dram_timing/ref.py`` re-exports it) and the default for small
   and medium traces.

2. ``simulate_channel_fast`` — a fully-vectorised analytic model: row
   hit/miss/conflict classification is *exact* (it only depends on the
   previous request to the same bank, computable with a stable sort), and
   the execution time is approximated as the max of the bus-occupancy bound
   and the busiest-bank latency bound.  Used for very long traces; its
   error against the scan engine is reported in EXPERIMENTS.md.

The TPU-native production implementation of engine (1) is the Pallas kernel
in ``repro/kernels/dram_timing`` (blocked request streaming HBM->VMEM with
bank state held in VMEM scratch across sequential grid steps).

Bank mapping (row-interleaved): line -> (col, bank, row) with
``col = line % lines_per_row``, ``bank = (line / lines_per_row) % nbanks``,
``row = line / (lines_per_row * nbanks)`` — sequential streams fill a row
buffer, then activate the next bank (as on real devices with open-page
policy and row:bank:col address mapping).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dram import DRAMConfig
from repro.core.trace import Trace

# Version tag of the simulation semantics (accelerator models + DRAM timing
# engines).  Bump whenever a change alters simulation *results*; the sweep
# result cache (repro.sweep.cache) keys on it, so stale cached reports are
# invalidated automatically.
ENGINE_VERSION = "1"


@dataclasses.dataclass
class TimingReport:
    time_ns: float
    cycles: int
    hits: int
    misses: int
    conflicts: int
    bytes_total: int
    bytes_read: int
    bytes_written: int
    requests: int
    channels_used: int
    bw_utilization: float  # achieved / peak over the busy window

    @staticmethod
    def zero() -> "TimingReport":
        return TimingReport(0.0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0.0)

    def to_dict(self) -> dict:
        """Plain-scalar dict (JSON round-trip via ``from_dict``)."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "TimingReport":
        return TimingReport(**d)


def decode(lines: np.ndarray, cfg: DRAMConfig) -> tuple[np.ndarray, np.ndarray]:
    """line index -> (bank, row) under the row-interleaved mapping."""
    lpr = cfg.lines_per_row
    nb = cfg.nbanks
    bank = (lines // lpr) % nb
    row = lines // (lpr * nb)
    return bank.astype(np.int32), row.astype(np.int32)


@partial(jax.jit, static_argnames=("nbanks", "tCL", "tRCD", "tRP", "tRC", "tBL", "lookahead"))
def _scan_engine(bank, row, nbanks, tCL, tRCD, tRP, tRC, tBL, lookahead):
    """Exact sequential engine.  All times in int32 memory-clock cycles.

    Pipelined model: column reads from an open row stream back-to-back at
    the bus rate (tBL per 64B line); precharge/activate for misses and
    conflicts overlap earlier transfers up to a bounded controller
    *lookahead* window (finite request queue), and activates in one bank
    respect tRC.  Per-bank state: open row, time the row can serve its
    first column (row_ready), last data-slot end (last_data), last
    activate (last_act); the channel data bus serialises transfers.

      hit:      slot = max(row_ready[b], bus_free) .. +tBL
      miss:     t_act = max(last_act[b]+tRC, last_data[b], bus_free-W)
      conflict: t_pre = max(last_data[b], bus_free-W)
                t_act = max(t_pre+tRP, last_act[b]+tRC)
      (then row_ready[b] = t_act + tRCD and served as a hit)

    The constant final column latency tCL is added once at the end.
    """
    n = bank.shape[0]

    def step(carry, req):
        open_row, row_ready, last_data, last_act, bus_free, hits, misses, conflicts = carry
        b, r = req
        valid = b >= 0  # padding requests (b == -1) are no-ops
        b = jnp.maximum(b, 0)
        cur = open_row[b]
        is_hit = (cur == r) & valid
        is_miss = (cur == jnp.int32(-1)) & valid
        is_conf = valid & ~is_hit & ~is_miss

        horizon = jnp.maximum(bus_free - lookahead, 0)
        t_pre = jnp.maximum(last_data[b], horizon)
        t_act_conf = jnp.maximum(t_pre + tRP, last_act[b] + tRC)
        t_act_miss = jnp.maximum(jnp.maximum(last_act[b] + tRC, last_data[b]), horizon)
        t_act = jnp.where(is_conf, t_act_conf, t_act_miss)
        new_row_ready = jnp.where(is_hit, row_ready[b], t_act + tRCD)

        slot_start = jnp.maximum(new_row_ready, bus_free)
        slot_end = slot_start + tBL
        new_bus_free = jnp.where(valid, slot_end, bus_free)

        open_row = jnp.where(valid, open_row.at[b].set(r), open_row)
        row_ready = jnp.where(valid, row_ready.at[b].set(new_row_ready), row_ready)
        last_data = jnp.where(valid, last_data.at[b].set(slot_end), last_data)
        last_act = jnp.where(
            is_hit | ~valid, last_act, last_act.at[b].set(t_act)
        )
        hits = hits + is_hit
        misses = misses + is_miss
        conflicts = conflicts + is_conf
        return (open_row, row_ready, last_data, last_act, new_bus_free,
                hits, misses, conflicts), None

    init = (
        jnp.full((nbanks,), -1, dtype=jnp.int32),
        jnp.zeros((nbanks,), dtype=jnp.int32),
        jnp.zeros((nbanks,), dtype=jnp.int32),
        jnp.full((nbanks,), -(tRC + 1), dtype=jnp.int32),
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
    )
    carry, _ = jax.lax.scan(step, init, (bank, row))
    bus_free, hits, misses, conflicts = carry[4], carry[5], carry[6], carry[7]
    return bus_free + tCL, hits, misses, conflicts


def classify_fast(bank: np.ndarray, row: np.ndarray, nbanks: int) -> np.ndarray:
    """Exact hit(0)/miss(1)/conflict(2) classification, vectorised.

    A request's class depends only on the previous request to the same bank
    (open-page policy), independent of timing."""
    n = len(bank)
    if n == 0:
        return np.zeros(0, dtype=np.int8)
    order = np.argsort(bank, kind="stable")
    sb, sr = bank[order], row[order]
    same_bank = sb[1:] == sb[:-1]
    cls_sorted = np.full(n, 1, dtype=np.int8)  # first touch of a bank: miss
    hit = np.zeros(n, dtype=bool)
    conf = np.zeros(n, dtype=bool)
    hit[1:] = same_bank & (sr[1:] == sr[:-1])
    conf[1:] = same_bank & (sr[1:] != sr[:-1])
    cls_sorted[hit] = 0
    cls_sorted[conf] = 2
    cls = np.empty(n, dtype=np.int8)
    cls[order] = cls_sorted
    return cls


def _pad_pow2(bank: np.ndarray, row: np.ndarray, minimum: int = 256):
    """Pad request arrays to the next power of two so the jitted scan engine
    compiles once per size class instead of once per trace length."""
    n = len(bank)
    target = minimum
    while target < n:
        target *= 2
    pad = target - n
    if pad:
        bank = np.concatenate([bank, np.full(pad, -1, dtype=bank.dtype)])
        row = np.concatenate([row, np.zeros(pad, dtype=row.dtype)])
    return bank, row


def simulate_channel_scan(trace: Trace, cfg: DRAMConfig) -> TimingReport:
    if trace.n == 0:
        return TimingReport.zero()
    bank, row = decode(trace.lines, cfg)
    bank, row = _pad_pow2(bank, row)
    t = cfg.timing_cycles()
    cycles, hits, misses, conflicts = _scan_engine(
        jnp.asarray(bank), jnp.asarray(row), cfg.nbanks,
        t["tCL"], t["tRCD"], t["tRP"], t["tRC"], t["tBL"],
        lookahead=16 * t["tBL"],
    )
    cycles = int(cycles)
    time_ns = cycles * cfg.tCK_ns
    peak_bytes = time_ns * cfg.bw_per_channel  # GB/s == B/ns
    return TimingReport(
        time_ns=time_ns,
        cycles=cycles,
        hits=int(hits),
        misses=int(misses),
        conflicts=int(conflicts),
        bytes_total=trace.bytes,
        bytes_read=trace.read_bytes,
        bytes_written=trace.write_bytes,
        requests=trace.n,
        channels_used=1,
        bw_utilization=trace.bytes / max(peak_bytes, 1e-9),
    )


def simulate_channel_fast(trace: Trace, cfg: DRAMConfig) -> TimingReport:
    """Analytic engine: exact request classification, approximate time.

    time ~= max( bus bound, busiest-bank latency bound ) where the bank
    bound accounts for tRC-limited back-to-back activates."""
    if trace.n == 0:
        return TimingReport.zero()
    bank, row = decode(trace.lines, cfg)
    cls = classify_fast(bank, row, cfg.nbanks)
    t = cfg.timing_cycles()
    hits = int((cls == 0).sum())
    misses = int((cls == 1).sum())
    conflicts = int((cls == 2).sum())

    bus_bound = trace.n * t["tBL"]
    # per-bank serial chain: hits stream at the bus rate; a miss costs
    # max(tRC, tRCD+tBL) in its bank, a conflict max(tRC, tRP+tRCD+tBL)
    # (matching the scan engine's per-bank dependency chain).
    miss_cost = max(t["tRC"], t["tRCD"] + t["tBL"])
    conf_cost = max(t["tRC"], t["tRP"] + t["tRCD"] + t["tBL"])
    act_cost = np.where(cls == 0, t["tBL"], np.where(cls == 1, miss_cost, conf_cost))
    per_bank = np.bincount(bank, weights=act_cost, minlength=cfg.nbanks)
    bank_bound = int(per_bank.max())
    cycles = int(max(bus_bound, bank_bound)) + t["tCL"]
    time_ns = cycles * cfg.tCK_ns
    peak_bytes = time_ns * cfg.bw_per_channel
    return TimingReport(
        time_ns=time_ns,
        cycles=cycles,
        hits=hits,
        misses=misses,
        conflicts=conflicts,
        bytes_total=trace.bytes,
        bytes_read=trace.read_bytes,
        bytes_written=trace.write_bytes,
        requests=trace.n,
        channels_used=1,
        bw_utilization=trace.bytes / max(peak_bytes, 1e-9),
    )


def simulate_dram(
    traces: list[Trace],
    cfg: DRAMConfig,
    engine: str = "auto",
    scan_cutoff: int = 2_000_000,
) -> TimingReport:
    """Simulate one trace per channel; total time = max over channels
    (channels operate independently); stats are summed."""
    assert len(traces) <= cfg.channels, (
        f"{len(traces)} traces for {cfg.channels}-channel {cfg.name}"
    )
    reports = []
    for tr in traces:
        if engine == "scan" or (engine == "auto" and tr.n <= scan_cutoff):
            reports.append(simulate_channel_scan(tr, cfg))
        else:
            reports.append(simulate_channel_fast(tr, cfg))
    if not reports:
        return TimingReport.zero()
    time_ns = max(r.time_ns for r in reports)
    tot_bytes = sum(r.bytes_total for r in reports)
    peak = time_ns * cfg.bw_per_channel * len(reports)
    return TimingReport(
        time_ns=time_ns,
        cycles=max(r.cycles for r in reports),
        hits=sum(r.hits for r in reports),
        misses=sum(r.misses for r in reports),
        conflicts=sum(r.conflicts for r in reports),
        bytes_total=tot_bytes,
        bytes_read=sum(r.bytes_read for r in reports),
        bytes_written=sum(r.bytes_written for r in reports),
        requests=sum(r.requests for r in reports),
        channels_used=len(reports),
        bw_utilization=tot_bytes / max(peak, 1e-9),
    )
