"""Distribution: mesh axes, parameter/activation/cache sharding rules,
collective helpers for the production meshes (single-pod 16x16, multi-pod
2x16x16), and the persistent spawn-based worker pool the sweep server
shards scenario chunks across (:mod:`repro.distributed.workpool`)."""
from repro.distributed.sharding import (
    batch_axes,
    batch_specs,
    cache_specs,
    param_specs,
    shardings,
)
from repro.distributed.workpool import WorkerPool

__all__ = ["WorkerPool", "batch_axes", "batch_specs", "cache_specs",
           "param_specs", "shardings"]
