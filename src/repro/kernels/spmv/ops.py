"""Public SpMV op over Graph objects (used by the SpMV workload benches)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph
from repro.kernels.spmv.ref import spmv_coo_ref, spmv_ell_ref, to_ell
from repro.kernels.spmv.spmv import spmv_ell_pallas


def spmv(
    g: Graph,
    x: np.ndarray,
    *,
    use_pallas: bool | None = None,
    block_rows: int = 256,
    interpret: bool | None = None,
) -> np.ndarray:
    """y = A @ x with A[dst, src] = weight (1.0 if unweighted)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    x = jnp.asarray(x, dtype=jnp.float32)
    if use_pallas or interpret:
        idx, val = to_ell(g.src, g.dst, g.weights, g.n, block_rows=block_rows)
        on_tpu = jax.default_backend() == "tpu"
        y = spmv_ell_pallas(
            jnp.asarray(idx), jnp.asarray(val), x,
            block_rows=block_rows,
            interpret=(not on_tpu) if interpret is None else interpret,
        )
        return np.asarray(y[: g.n])
    w = g.weights if g.weights is not None else np.ones(g.m, dtype=np.float32)
    return np.asarray(spmv_coo_ref(jnp.asarray(g.src), jnp.asarray(g.dst),
                                   jnp.asarray(w), x, g.n))
