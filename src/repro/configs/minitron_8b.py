"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679; hf]."""
from repro.configs.base import ArchConfig, register

MINITRON_8B = register(ArchConfig(
    arch="minitron_8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256_000,
    rope_theta=10_000.0,
    notes="pruned nemotron; GQA kv=8; squared-relu FFN in the original, "
          "SwiGLU here (uniform FFN across the zoo; param count matched)",
))
