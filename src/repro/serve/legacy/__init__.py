"""Legacy LLM-serving scaffolding (continuous-batching ``ServeEngine`` over
``repro.models`` plus the jitted prefill/decode steps).

This predates the graph-simulation service and is unrelated to it; it is
kept importable for the dry-run/roofline shape coverage and its tests, but
``repro.serve`` itself is the sweep server (simulation-as-a-service).
"""
